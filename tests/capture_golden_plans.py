"""Capture pre-refactor golden values for the plan-registry refactor.

Run from the repo root (PYTHONPATH=src python tests/capture_golden_plans.py)
against the PRE-refactor engine; writes tests/golden/plans_prerefactor.json.
tests/test_plans.py pins the refactored plans against these values bitwise.
"""
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.configs.base import FLConfig
from repro.core import rounds as rounds_lib
from repro.data.synthetic import (make_federated, make_population,
                                  round_batches, stack_federation)
from repro.models.spec import get_model_spec, meta_for
from repro.train import fl_driver

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "golden",
                   "plans_prerefactor.json")


def parallel_case():
    fed = make_federated(0, "unsw", n_samples=600, n_clients=8)
    fl = FLConfig(n_clients=8, clients_per_round=3, rounds=6, local_epochs=2,
                  local_batch=16, local_lr=0.08, dp_enabled=True,
                  dp_mode="clipped", dp_epsilon=200.0, dp_clip=5.0,
                  fault_tolerance=True, failure_prob=0.1)
    r = fl_driver.run_fl(fed, fl, "proposed", seed=3, rounds=6, eval_every=2)
    return {"history": r.history, "sim_time_s": r.sim_time_s}


def serial_case():
    """Two direct make_serial_round steps (the driver never routes here)."""
    fed = make_federated(1, "unsw", n_samples=400, n_clients=6)
    fl = FLConfig(n_clients=6, clients_per_round=3, rounds=4, local_epochs=2,
                  local_batch=8, local_lr=0.05, dp_enabled=True,
                  dp_mode="clipped", dp_epsilon=100.0, dp_clip=2.0,
                  plan="client_serial", serial_clients_in_step=3,
                  fault_tolerance=True, failure_prob=0.1)
    meta = meta_for(fed, hidden=16)
    spec = get_model_spec(fl.model, meta)
    key = jax.random.key(7)
    params = spec.init(jax.random.fold_in(key, 0))
    sizes = fed.data_sizes()
    state = rounds_lib.init_round_state(
        params, fl, jax.random.fold_in(key, 1), n_clients=fed.n_clients,
        data_size=jnp.asarray(sizes / sizes.mean()),
        data_quality=jnp.asarray(fed.label_entropy()))
    step = jax.jit(rounds_lib.make_serial_round(spec.loss, fl, fed.n_clients))
    rng = np.random.default_rng(5)
    out = {"global_loss": [], "k_effective": [], "sel_mask": [], "norms": []}
    for _ in range(2):
        batches = jax.tree.map(jnp.asarray, round_batches(
            rng, fed, fl.local_epochs, fl.local_batch))
        batches = jax.tree.map(lambda x: x[: fl.serial_clients_in_step],
                               batches)
        state, m = step(state, batches)
        out["global_loss"].append(float(m.global_loss))
        out["k_effective"].append(float(m.k_effective))
        out["sel_mask"].append(np.asarray(m.sel_mask).tolist())
        out["norms"].append(np.asarray(m.update_norms).tolist())
    return out


def cohort_case():
    pop = make_population(0, n_clients=64, pool_samples=600,
                          members_per_client=16)
    fl = FLConfig(n_clients=64, clients_per_round=8, k_max=8, rounds=6,
                  local_epochs=2, local_batch=16, local_lr=0.08,
                  fault_tolerance=True, failure_prob=0.05)
    r = fl_driver.run_fl_population(pop, fl, seeds=(0,), rounds=6,
                                    eval_every=3)[0][0]
    return {"history": r.history, "sim_time_s": r.sim_time_s}


def sweep_case():
    """A (fault_process x rate) sweep, history columns per lane."""
    fed = make_federated(0, "unsw", n_samples=600, n_clients=8)
    fl = FLConfig(n_clients=8, clients_per_round=3, rounds=4, local_epochs=2,
                  local_batch=16, local_lr=0.08, dp_enabled=True,
                  dp_mode="clipped", dp_epsilon=200.0, dp_clip=5.0,
                  fault_tolerance=True, failure_prob=0.05)
    cells = [{"fault_process": 0.0, "failure_prob": 0.3},
             {"fault_process": 1.0, "failure_prob": 0.3},
             {"fault_process": 3.0, "failure_prob": 0.3}]
    sweep = fl_driver.run_fl_sweep(fed, fl, cells, seeds=(0, 1), rounds=4,
                                   eval_every=2)
    return {"histories": [[r.history for r in row] for row in sweep]}


def main():
    golden = {
        "parallel": parallel_case(),
        "serial": serial_case(),
        "cohort": cohort_case(),
        "sweep": sweep_case(),
    }
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(golden, f, indent=1, sort_keys=True)
    print("wrote", OUT)


if __name__ == "__main__":
    main()
