"""Vectorised ``road_like`` vs the per-window loop oracle (ISSUE 3
satellite): the batched generator must be a *statistical* drop-in — the
two draw the RNG in different orders, so equality is distributional, not
sample-for-sample.
"""
import numpy as np

from repro.data.synthetic import _road_like_loop, road_like

N = 1_500


def test_road_like_matches_loop_oracle_statistically():
    Xv, yv, _ = road_like(np.random.default_rng(0), N)
    Xl, yl, _ = _road_like_loop(np.random.default_rng(1), N)
    assert Xv.shape == Xl.shape == (N, 30)
    assert abs(float(yv.mean()) - float(yl.mean())) < 0.05

    # standardisation contract: zero mean; unit variance except the one
    # constant feature (c0 of signal 0 is 1.0 by definition in BOTH
    # generators, so its standardised column is identically 0)
    for X in (Xv, Xl):
        np.testing.assert_allclose(X.mean(0), 0.0, atol=1e-5)
        std = X.std(0)
        assert np.all((np.abs(std - 1.0) < 1e-3) | (std < 1e-6))
        assert (std < 1e-6).sum() == 1

    # class-conditional feature means agree (units of feature σ; the max
    # over 30 features of two independent ~N(0, 2/n_cls) samples stays well
    # under 0.3 — looseness is sampling noise, not generator drift)
    for cls in (0, 1):
        d = np.abs(Xv[yv == cls].mean(0) - Xl[yl == cls].mean(0))
        assert d.max() < 0.3, (cls, d.max())
        assert d.mean() < 0.1, (cls, d.mean())


def test_road_like_attack_signature_preserved():
    """The masquerade must stay detectable-but-subtle in the vectorised
    generator exactly as in the oracle (same check as test_substrate's)."""
    rng = np.random.default_rng(0)
    X, y, _ = road_like(rng, 400)
    d = np.abs(X[y == 1].mean(0) - X[y == 0].mean(0))
    assert d.max() > 0.1


def test_road_like_deterministic_per_seed():
    X1, y1, _ = road_like(np.random.default_rng(7), 200)
    X2, y2, _ = road_like(np.random.default_rng(7), 200)
    np.testing.assert_array_equal(X1, X2)
    np.testing.assert_array_equal(y1, y2)


def test_road_like_handles_all_normal_and_all_attack():
    X0, y0, _ = road_like(np.random.default_rng(3), 64, attack_rate=0.0)
    assert y0.sum() == 0 and np.isfinite(X0).all()
    X1, y1, _ = road_like(np.random.default_rng(3), 64, attack_rate=1.0)
    assert y1.sum() == 64 and np.isfinite(X1).all()
