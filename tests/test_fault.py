"""Failure-scenario engine tests (ISSUE 5).

* the ``iid`` process is the pre-engine draw, key for key — the
  load-bearing bitwise pin (the round step consumes only the emitted
  ``fail_at``, so identical draws mean identical trajectories), plus
  engine-level default-lane equality in the style of
  ``tests/test_models.py``;
* empirical marginal failure rate of every process matches its
  ``failure_prob`` parameter;
* the Markov process shows the configured burst autocorrelation
  (``P(fail_{t+1} | fail_t) ≈ 1 − 1/fault_burst``), which i.i.d. lacks;
* stragglers stretch the simulated round time without killing updates;
* a (process × rate) frontier is runtime lanes: ONE ``_get_runner`` miss;
* the reliability EMA decays failed clients' utility only when the
  runtime coupling weight is on.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig, fl_params, fl_static
from repro.core import selection as sel_lib
from repro.data.synthetic import make_federated
from repro.fault import (PROCESSES, FaultState, fault_step, iid_fail_times,
                         init_fault_state, process_code)
from repro.train import fl_driver

LOCAL_STEPS = 6


@pytest.fixture(scope="module")
def fed():
    return make_federated(0, "unsw", n_samples=900, n_clients=8)


@pytest.fixture(scope="module")
def fl():
    return FLConfig(n_clients=8, clients_per_round=3, rounds=8,
                    local_epochs=2, local_batch=16, local_lr=0.08,
                    dp_enabled=True, dp_mode="clipped", dp_epsilon=200.0,
                    dp_clip=5.0, fault_tolerance=True, failure_prob=0.05)


def _pr(**kw):
    return fl_params(FLConfig(**kw))


def _chain(pr, n, rounds, seed=0):
    """Drive fault_step for ``rounds`` rounds; returns the [rounds, n]
    failure indicator matrix and the final state."""
    st = init_fault_state(n)
    key = jax.random.key(seed)
    step = jax.jit(lambda s, k: fault_step(s, k, pr, n, LOCAL_STEPS))
    rows = []
    for r in range(rounds):
        fail_at, slow, st = step(st, jax.random.fold_in(key, r))
        rows.append(np.asarray(fail_at) < LOCAL_STEPS)
    return np.stack(rows), st


# ---------------------------------------------------------------------------
# bitwise pin: iid process == pre-engine draw
# ---------------------------------------------------------------------------


def test_process_registry():
    assert PROCESSES == ("iid", "markov", "weibull", "straggler")
    assert process_code("iid") == 0.0 and process_code("straggler") == 3.0
    with pytest.raises(ValueError):
        process_code("no_such_process")


def test_iid_process_is_prerefactor_draw_bitwise():
    """The engine's default lane consumed, pre-refactor:
    ``bernoulli(fold_in(k_fail, 1), p)`` then ``randint(fold_in(k_fail, 2))``.
    The iid process must reproduce those arrays exactly — the round step
    consumes only ``fail_at``, so equal draws are equal trajectories."""
    n, p = 16, 0.3
    pr = _pr(failure_prob=p)
    k_fail = jax.random.fold_in(jax.random.key(42), 7)
    fail_at, slow, _ = fault_step(init_fault_state(n), k_fail, pr, n,
                                  LOCAL_STEPS)
    fails_old = jax.random.bernoulli(jax.random.fold_in(k_fail, 1), p, (n,))
    step_old = jax.random.randint(jax.random.fold_in(k_fail, 2), (n,), 0,
                                  LOCAL_STEPS)
    expected = jnp.where(fails_old, step_old, LOCAL_STEPS)
    np.testing.assert_array_equal(np.asarray(fail_at), np.asarray(expected))
    np.testing.assert_array_equal(np.asarray(slow), np.ones(n, np.float32))
    # the serial plan's historical keying rides the shared helper
    serial = iid_fail_times(k_fail, jax.random.fold_in(k_fail, 1), p, n,
                            LOCAL_STEPS)
    fails_s = jax.random.bernoulli(k_fail, p, (n,))
    step_s = jax.random.randint(jax.random.fold_in(k_fail, 1), (n,), 0,
                                LOCAL_STEPS)
    np.testing.assert_array_equal(
        np.asarray(serial), np.asarray(jnp.where(fails_s, step_s, LOCAL_STEPS)))


@pytest.mark.parametrize("ft", [True, False])
def test_default_engine_lane_is_explicit_iid_lane(fed, fl, ft):
    """A config that never mentions the fault-engine fields and one that
    sets them to their explicit iid defaults are the same lane — with and
    without fault tolerance (the ``fault_tolerance=False`` pre-refactor
    pin)."""
    base = dataclasses.replace(fl, fault_tolerance=ft)
    explicit = dataclasses.replace(base, fault_process=process_code("iid"),
                                   fault_util_w=0.0)
    assert fl_static(explicit) == fl_static(base)
    a = fl_driver.run_fl(fed, base, "proposed", seed=2, rounds=6, eval_every=3)
    b = fl_driver.run_fl(fed, explicit, "proposed", seed=2, rounds=6,
                         eval_every=3)
    assert a.history == b.history


# ---------------------------------------------------------------------------
# marginal rates + burstiness
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("proc,rate", [
    ("iid", 0.1), ("iid", 0.3),
    ("markov", 0.1), ("markov", 0.3),
    ("weibull", 0.1), ("weibull", 0.3),
])
def test_marginal_failure_rate_matches_parameter(proc, rate):
    n, rounds = 256, 160
    pr = _pr(failure_prob=rate, fault_process=process_code(proc))
    fails, _ = _chain(pr, n, rounds, seed=hash(proc) % 1000)
    # skip a short burn-in: markov starts all-up, weibull all age-0
    emp = fails[20:].mean()
    se = np.sqrt(rate * (1 - rate) / (n * (rounds - 20)))
    # correlated processes have fewer effective samples; 5x the iid SE
    # plus a 10% relative calibration allowance is comfortably tight
    tol = 5 * se + 0.1 * rate
    assert abs(emp - rate) < tol, (proc, rate, emp, tol)


def test_straggler_never_fails_and_stretches_time(fl):
    n, rate = 64, 0.4
    pr = _pr(failure_prob=rate, fault_process=process_code("straggler"),
             straggler_slow=4.0)
    k = jax.random.fold_in(jax.random.key(3), 0)
    fail_at, slow, _ = fault_step(init_fault_state(n), k, pr, n, LOCAL_STEPS)
    assert (np.asarray(fail_at) == LOCAL_STEPS).all(), "stragglers must survive"
    s = np.asarray(slow)
    assert set(np.unique(s)) <= {1.0, 4.0}
    frac = (s > 1.0).mean()
    assert 0.15 < frac < 0.7  # ~rate of the clients are stretched
    # the time model waits for the slowest selected client
    util = sel_lib.init_utility_state(n, key=jax.random.key(0))
    mask = jnp.ones((n,), jnp.float32)
    failed = jnp.zeros((n,), jnp.float32)
    t_plain = float(fl_driver.simulate_round_time(fl, util, mask, failed))
    t_slow = float(fl_driver.simulate_round_time(fl, util, mask, failed,
                                                 slow=jnp.asarray(s)))
    assert t_slow > t_plain
    # all-ones slow factors are an exact no-op
    t_ones = float(fl_driver.simulate_round_time(fl, util, mask, failed,
                                                 slow=jnp.ones((n,))))
    assert t_ones == t_plain


def test_markov_burst_autocorrelation():
    """P(fail_{t+1} | fail_t) must be ≈ 1 − 1/burst for the Markov process
    (configured persistence), while iid shows ≈ the marginal rate."""
    n, rounds, rate, burst = 256, 200, 0.15, 5.0
    for proc, expect in (("markov", 1.0 - 1.0 / burst), ("iid", rate)):
        pr = _pr(failure_prob=rate, fault_process=process_code(proc),
                 fault_burst=burst)
        fails, _ = _chain(pr, n, rounds, seed=11)
        prev, nxt = fails[20:-1], fails[21:]
        p_cond = nxt[prev].mean()
        assert abs(p_cond - expect) < 0.08, (proc, p_cond, expect)


def test_markov_marginal_holds_at_high_rate_low_burst():
    """enter = p/(L(1−p)) > 1 is unrealisable; the burst floor L ≥ p/(1−p)
    must keep the stationary marginal at failure_prob instead of silently
    clipping to a lower rate (review fix: p=0.6, burst=1 used to realise
    0.5, a 17% miscalibration)."""
    n, rounds, rate = 256, 200, 0.6
    pr = _pr(failure_prob=rate, fault_process=process_code("markov"),
             fault_burst=1.0)
    fails, _ = _chain(pr, n, rounds, seed=13)
    emp = fails[20:].mean()
    assert abs(emp - rate) < 0.05, emp


def test_weibull_age_resets_on_failure():
    n, rounds = 64, 40
    pr = _pr(failure_prob=0.3, fault_process=process_code("weibull"))
    fails, st = _chain(pr, n, rounds, seed=5)
    age = np.asarray(st.age)
    assert fails.any() and (age >= 0).all()
    # a client that failed on the last round has its age reset to 0
    last = fails[-1]
    assert (age[last] == 0.0).all()
    assert (age[~last] >= 1.0).all()


# ---------------------------------------------------------------------------
# runtime-lane frontier: one compile
# ---------------------------------------------------------------------------


def test_fault_frontier_single_compile(fed, fl):
    """A whole (process × rate) grid is runtime lanes: one _get_runner miss."""
    cells = [{"fault_process": process_code(p), "failure_prob": r,
              "fault_util_w": 1.0}
             for p in PROCESSES for r in (0.05, 0.4)]
    fl_driver._RUNNER_CACHE.clear()
    m0 = fl_driver.RUNNER_STATS["misses"]
    sweep = fl_driver.run_fl_sweep(fed, fl, cells, seeds=(0,), rounds=4,
                                   eval_every=2)
    assert fl_driver.RUNNER_STATS["misses"] - m0 == 1
    assert len(sweep) == len(cells)
    # straggler lanes never record failures
    for c, row in zip(cells, sweep):
        if c["fault_process"] == process_code("straggler"):
            assert all(x == 0.0 for r in row for x in r.history["fail"])


# ---------------------------------------------------------------------------
# selection coupling: reliability EMA
# ---------------------------------------------------------------------------


def test_fail_ema_tracks_attempted_failures():
    fl = FLConfig(n_clients=6)
    s = sel_lib.init_utility_state(6, key=jax.random.key(0))
    contrib = jnp.array([1, 0, 0, 0, 1, 0], jnp.float32)   # survivors
    attempted = jnp.array([1, 1, 0, 0, 1, 0], jnp.float32)  # incl. the failed
    failed = jnp.array([0, 1, 0, 0, 0, 0], jnp.float32)
    pre = jnp.full((6,), 2.0)
    post = jnp.full((6,), 1.0)
    s2 = sel_lib.update_utility_state(s, contrib, pre, post, fl,
                                      attempted=attempted, failed=failed)
    ema = np.asarray(s2.fail_ema)
    assert ema[1] > 0          # attempted and failed -> reliability drops
    assert ema[0] == ema[4] == 0.0  # attempted and survived
    assert (ema[[2, 3, 5]] == 0.0).all()  # not attempted: untouched
    # legacy call sites (no failed kwarg) leave the EMA alone
    s3 = sel_lib.update_utility_state(s2, contrib, pre, post, fl)
    np.testing.assert_array_equal(np.asarray(s3.fail_ema), ema)


def test_fault_weight_decays_utility_and_zero_weight_is_bitwise_noop():
    fl = FLConfig(n_clients=6)
    s = sel_lib.init_utility_state(6, key=jax.random.key(0))
    s = s._replace(fail_ema=jnp.array([0, 0.9, 0, 0, 0, 0], jnp.float32))
    base = sel_lib.compute_utility(s, fl)
    off = sel_lib.compute_utility(s, fl, fault_w=jnp.asarray(0.0))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(off))
    on = np.asarray(sel_lib.compute_utility(s, fl, fault_w=jnp.asarray(2.0)))
    assert on[1] < np.asarray(base)[1]
    np.testing.assert_array_equal(np.delete(on, 1),
                                  np.delete(np.asarray(base), 1))
