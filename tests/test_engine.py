"""Compiled-engine tests (ISSUE 1): the scan/vmap engine must be a drop-in
replacement for the legacy per-round Python loop.

* scanned single-seed ``run_fl`` matches the legacy loop's final accuracy
  within ±0.02 and its ε exactly (same accountant over the same rounds);
* ``run_fl_batch`` over 3 seeds matches 3 sequential scanned runs lane for
  lane (vmap must not change semantics);
* the jit-safe time model is jit-invariant and ordering-sane;
* DP routing (Pallas kernel vs kernels/ref fallback) is observationally
  neutral inside ``privatize_update``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core import dp as dp_lib
from repro.data.synthetic import (make_federated, sample_round_batches,
                                  stack_federation)
from repro.train import fl_driver

ROUNDS = 30
EVAL_EVERY = 5


@pytest.fixture(scope="module")
def fed():
    return make_federated(0, "unsw", n_samples=2_000, n_clients=10)


@pytest.fixture(scope="module")
def fl():
    return FLConfig(n_clients=10, clients_per_round=4, rounds=ROUNDS,
                    local_epochs=3, local_batch=32, local_lr=0.08,
                    dp_enabled=True, dp_mode="clipped", dp_epsilon=200.0,
                    dp_clip=5.0, fault_tolerance=True, failure_prob=0.05)


# ---------------------------------------------------------------------------
# scan engine vs legacy loop
# ---------------------------------------------------------------------------


def test_scan_engine_matches_legacy(fed, fl):
    """The two engines draw independent batch streams (device jax.random vs
    host NumPy), so per-seed accuracy is a statistical quantity: compare the
    mean over 3 seeds at the ISSUE tolerance, ε exactly."""
    seeds = (0, 1, 2)
    legacy = [fl_driver.run_fl_legacy(fed, fl, "proposed", seed=s,
                                      rounds=ROUNDS, eval_every=EVAL_EVERY)
              for s in seeds]
    scan = fl_driver.run_fl_batch(fed, fl, "proposed", seeds=seeds,
                                  rounds=ROUNDS, eval_every=EVAL_EVERY)
    acc_l = float(np.mean([r.accuracy for r in legacy]))
    acc_s = float(np.mean([r.accuracy for r in scan]))
    assert abs(acc_s - acc_l) <= 0.02
    for l, s in zip(legacy, scan):
        assert abs(s.eps_spent - l.eps_spent) <= 1e-6
        assert s.rounds == l.rounds
        # same eval grid, same history schema
        assert s.history["round"] == l.history["round"]
        assert set(s.history) == set(l.history)
        # the simulated-time model is the same function in both engines;
        # totals differ only through which clients were selected/failed
        assert s.sim_time_s == pytest.approx(l.sim_time_s, rel=0.25)


def test_partial_eval_block_matches_legacy_grid(fed, fl):
    """rounds % eval_every != 0 exercises the trailing partial scan block;
    the eval grid must still match the legacy loop's exactly."""
    legacy = fl_driver.run_fl_legacy(fed, fl, "random", seed=1, rounds=12,
                                     eval_every=5)
    scan = fl_driver.run_fl(fed, fl, "random", seed=1, rounds=12, eval_every=5)
    assert scan.history["round"] == [5, 10, 12] == legacy.history["round"]
    assert len(scan.history["acc"]) == 3
    # cumulative time must be nondecreasing across eval points
    assert np.all(np.diff(scan.history["cum_time"]) >= 0)


def test_batch_matches_sequential_runs(fed, fl):
    seeds = (0, 3, 7)
    batch = fl_driver.run_fl_batch(fed, fl, "proposed", seeds=seeds,
                                   rounds=ROUNDS, eval_every=EVAL_EVERY)
    for seed, b in zip(seeds, batch):
        single = fl_driver.run_fl(fed, fl, "proposed", seed=seed,
                                  rounds=ROUNDS, eval_every=EVAL_EVERY)
        assert b.seed == seed
        # each vmap lane keys off jax.random.key(seed): identical math
        np.testing.assert_allclose(b.accuracy, single.accuracy, atol=1e-5)
        np.testing.assert_allclose(b.auc, single.auc, atol=1e-4)
        np.testing.assert_allclose(b.sim_time_s, single.sim_time_s, rtol=1e-5)
        np.testing.assert_allclose(b.history["acc"], single.history["acc"],
                                   atol=1e-5)
    assert b.eps_spent == pytest.approx(single.eps_spent, abs=1e-12)


def test_batch_is_deterministic_and_cached(fed, fl):
    """Same (config, seeds) -> identical results; the second call reuses the
    compiled runner (no recompile — this is what sweeps rely on)."""
    a = fl_driver.run_fl_batch(fed, fl, "proposed", seeds=(0, 3, 7),
                               rounds=ROUNDS, eval_every=EVAL_EVERY)
    n_cached = len(fl_driver._RUNNER_CACHE)
    b = fl_driver.run_fl_batch(fed, fl, "proposed", seeds=(0, 3, 7),
                               rounds=ROUNDS, eval_every=EVAL_EVERY)
    assert len(fl_driver._RUNNER_CACHE) == n_cached
    for ra, rb in zip(a, b):
        assert ra.accuracy == rb.accuracy
        assert ra.history == rb.history


# ---------------------------------------------------------------------------
# jit-safe time model
# ---------------------------------------------------------------------------


def _time_args(fl, n=10, sel=4, failures=0):
    from repro.core import selection as sel_lib

    util = sel_lib.init_utility_state(n, key=jax.random.key(0))
    mask = jnp.zeros((n,)).at[:sel].set(1.0)
    failed = jnp.zeros((n,)).at[:failures].set(1.0)
    return util, mask, failed


def test_simulate_round_time_is_jit_invariant(fl):
    util, mask, failed = _time_args(fl, failures=2)
    eager = fl_driver.simulate_round_time(fl, util, mask, failed)
    jitted = jax.jit(
        lambda u, m, f: fl_driver.simulate_round_time(fl, u, m, f)
    )(util, mask, failed)
    np.testing.assert_allclose(float(eager), float(jitted), rtol=1e-6)


def test_simulate_round_time_ordering(fl):
    util, mask, _ = _time_args(fl)
    zero = jnp.zeros_like(mask)
    t_clean = float(fl_driver.simulate_round_time(fl, util, mask, zero))
    # failures cost time, under fault tolerance and (more) without it
    _, _, failed = _time_args(fl, failures=3)
    t_fail_ft = float(fl_driver.simulate_round_time(fl, util, mask, failed))
    no_ft = dataclasses.replace(fl, fault_tolerance=False)
    t_clean_noft = float(fl_driver.simulate_round_time(no_ft, util, mask, zero))
    t_fail_noft = float(fl_driver.simulate_round_time(no_ft, util, mask, failed))
    assert t_fail_ft > t_clean
    assert t_fail_noft > t_clean_noft
    # empty selection degenerates to pure communication time
    t_empty = float(fl_driver.simulate_round_time(fl, util, zero, zero))
    assert t_empty == pytest.approx(0.35)


# ---------------------------------------------------------------------------
# device-side batch sampling
# ---------------------------------------------------------------------------


def test_sample_round_batches_respects_client_sizes(fed):
    stack = stack_federation(fed)
    b = jax.jit(
        lambda k: sample_round_batches(k, stack, local_steps=4, batch=8)
    )(jax.random.key(0))
    assert b["x"].shape == (fed.n_clients, 4, 8, fed.n_features)
    assert b["y"].shape == (fed.n_clients, 4, 8)
    # every sampled row must exist in that client's shard (never padding):
    # rows are drawn from [0, size_i), so labels match the client's own data
    for ci in (0, fed.n_clients - 1):
        rows = np.asarray(b["x"][ci]).reshape(-1, fed.n_features)
        src = np.asarray(stack.x[ci][: int(stack.sizes[ci])])
        for r in rows[:8]:
            assert np.isclose(src, r, atol=1e-6).all(axis=1).any()


# ---------------------------------------------------------------------------
# DP routing equivalence (kernel vs fallback)
# ---------------------------------------------------------------------------


def test_privatize_update_routing_is_neutral():
    """use_kernel=True (ref fallback on CPU / Pallas on TPU) and the plain
    jnp path must produce the same noised update — routing never changes
    the mechanism."""
    key = jax.random.key(7)
    tree = {"w": jax.random.normal(key, (65, 33)) * 3.0,
            "b": jax.random.normal(jax.random.fold_in(key, 1), (129,))}
    a, na = dp_lib.privatize_update(tree, key, mode="clipped", clip=0.7,
                                    sigma=0.2, use_kernel=True)
    b, nb = dp_lib.privatize_update(tree, key, mode="clipped", clip=0.7,
                                    sigma=0.2, use_kernel=False)
    np.testing.assert_allclose(float(na), float(nb), rtol=1e-6)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5)
