"""Model-pluggable engine tests (ISSUE 4).

* the ``mlp`` ModelSpec is the pre-refactor wiring, function for function —
  the load-bearing bitwise-equivalence proof: the engine consumes ONLY the
  spec's ``init``/``loss``/``logits`` surface, so identical functions mean
  an identical traced program;
* per-model engine-vs-legacy equivalence on the raw-ROAD federation;
* runner-cache statics keying: one compile per model static, zero on rerun;
* the window-native data path (``road_raw`` + ``feature_shape``);
* regression tests for the two ISSUE-4 bugfixes: adaptive-K first-round
  shrink (``core/selection.update_k``) and fractional-K privacy
  under-accounting (the accountant's q must match the realised selection
  count, ``ceil(k_eff)``).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig, fl_static
from repro.core import selection as sel_lib
from repro.data.synthetic import make_federated, road_like
from repro.models import mlp as mlp_lib
from repro.models.spec import (DataMeta, get_model_spec, meta_for,
                               model_names)
from repro.train import fl_driver
from repro.train.fl_driver import realized_cohort_fraction

ROUNDS = 10
EVAL_EVERY = 5


@pytest.fixture(scope="module")
def fed_road():
    return make_federated(0, "road_raw", n_samples=900, n_clients=8)


@pytest.fixture(scope="module")
def fl():
    return FLConfig(n_clients=8, clients_per_round=3, rounds=ROUNDS,
                    local_epochs=2, local_batch=16, local_lr=0.08,
                    dp_enabled=True, dp_mode="clipped", dp_epsilon=200.0,
                    dp_clip=5.0, fault_tolerance=True, failure_prob=0.05)


# ---------------------------------------------------------------------------
# registry + spec contract
# ---------------------------------------------------------------------------


def test_registry_has_builtin_models():
    assert set(model_names()) >= {"mlp", "cnn", "rglru", "ssm", "attn"}
    with pytest.raises(KeyError, match="unknown FLConfig.model"):
        get_model_spec("no_such_model", DataMeta(4, 2, 8, (4,)))


def test_window_models_reject_tabular_meta():
    tab = DataMeta(n_features=42, n_classes=2, hidden=64,
                   feature_shape=(42,))
    for name in ("cnn", "rglru", "ssm", "attn"):
        with pytest.raises(ValueError, match="window-native"):
            get_model_spec(name, tab)


def test_mlp_spec_is_prerefactor_wiring_bitwise(fed_road):
    """The engine consumes only ``spec.init``/``loss``/``logits`` (plus the
    metrics derived from ``logits``).  For ``model='mlp'`` those must be the
    exact pre-refactor computations: ``loss``/``logits`` the SAME function
    objects the engine used to close over, ``init`` bitwise equal to
    ``init_mlp``, and the derived metrics bitwise equal to models/mlp's —
    identical inputs to ``make_parallel_round`` + identical eval math is an
    identical traced program, i.e. pre/post-refactor bitwise equality."""
    fed = make_federated(3, "unsw", n_samples=600, n_clients=6)
    meta = meta_for(fed, hidden=48)
    spec = get_model_spec("mlp", meta)
    assert spec.loss is mlp_lib.mlp_loss
    assert spec.logits is mlp_lib.mlp_logits

    key = jax.random.key(11)
    a = spec.init(key)
    b = mlp_lib.init_mlp(key, fed.n_features, 48, fed.n_classes)
    assert jax.tree.structure(a) == jax.tree.structure(b)
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))

    x = jnp.asarray(fed.test_x[:64])
    y = jnp.asarray(fed.test_y[:64])
    np.testing.assert_array_equal(
        np.asarray(spec.accuracy(a, x, y)),
        np.asarray(mlp_lib.accuracy(b, x, y)))
    np.testing.assert_array_equal(
        np.asarray(spec.predict_proba(a, x)),
        np.asarray(mlp_lib.mlp_predict_proba(b, x)))


def test_default_model_lane_is_explicit_mlp_lane(fed_road, fl):
    """``FLConfig.model`` defaults to ``mlp``: a config that never mentions
    the field and one that sets it explicitly are the same static cell and
    produce identical histories."""
    explicit = dataclasses.replace(fl, model="mlp")
    assert fl_static(explicit) == fl_static(fl)
    a = fl_driver.run_fl(fed_road, fl, "proposed", seed=2, rounds=6,
                         eval_every=3)
    b = fl_driver.run_fl(fed_road, explicit, "proposed", seed=2, rounds=6,
                         eval_every=3)
    assert a.history == b.history


# ---------------------------------------------------------------------------
# per-model engine vs legacy oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("model", ["mlp", "cnn", "rglru", "ssm", "attn"])
def test_engine_matches_legacy_per_model(fed_road, fl, model):
    """The scanned engine and the legacy loop draw independent batch
    streams, so metrics agree statistically; ε, the eval grid and the
    history schema must agree exactly — for every registered model."""
    cfg = dataclasses.replace(fl, model=model)
    legacy = fl_driver.run_fl_legacy(fed_road, cfg, "proposed", seed=0,
                                     rounds=ROUNDS, eval_every=EVAL_EVERY)
    scan = fl_driver.run_fl(fed_road, cfg, "proposed", seed=0,
                            rounds=ROUNDS, eval_every=EVAL_EVERY)
    assert scan.eps_spent == pytest.approx(legacy.eps_spent, abs=1e-6)
    assert scan.history["round"] == legacy.history["round"]
    assert set(scan.history) == set(legacy.history)
    assert abs(scan.accuracy - legacy.accuracy) <= 0.25
    assert np.all(np.diff(scan.history["cum_time"]) >= 0)


# ---------------------------------------------------------------------------
# runner-cache statics keying
# ---------------------------------------------------------------------------


def test_one_compile_per_model_static(fed_road, fl):
    """A model grid compiles once per architecture: N models -> N misses,
    rerunning any of them -> pure cache hits."""
    models = ("mlp", "cnn", "rglru", "ssm", "attn")
    cfgs = [dataclasses.replace(fl, model=m) for m in models]
    for c in cfgs:  # warm every model's runner
        fl_driver.run_fl_batch(fed_road, c, "proposed", seeds=(0, 1),
                               rounds=6, eval_every=3)
    m0 = fl_driver.RUNNER_STATS["misses"]
    h0 = fl_driver.RUNNER_STATS["hits"]
    for c in cfgs:
        fl_driver.run_fl_batch(fed_road, c, "proposed", seeds=(0, 1),
                               rounds=6, eval_every=3)
    assert fl_driver.RUNNER_STATS["misses"] == m0, \
        "rerunning a model grid must not recompile"
    assert fl_driver.RUNNER_STATS["hits"] == h0 + len(models)
    # a model the cache has not seen at these shapes is a genuine miss
    fl_driver.run_fl_batch(fed_road, cfgs[1], "proposed", seeds=(0, 1),
                           rounds=7, eval_every=3)
    assert fl_driver.RUNNER_STATS["misses"] == m0 + 1


def test_sweep_rejects_model_mismatch(fed_road, fl):
    """model is STATIC — it cannot ride the runtime lane axis."""
    bad = dataclasses.replace(fl, model="cnn")
    with pytest.raises(ValueError, match="STATIC"):
        fl_driver.run_fl_sweep(fed_road, fl, [fl, bad], seeds=(0,), rounds=4)


# ---------------------------------------------------------------------------
# window-native data path
# ---------------------------------------------------------------------------


def test_road_raw_feature_shape_roundtrip():
    fed = make_federated(1, "road_raw", n_samples=300, n_clients=4)
    assert fed.feature_shape == (64, 6)
    assert int(np.prod(fed.feature_shape)) == fed.n_features == 384
    # unflattening recovers time-major windows: feature j of signal s at
    # time t sits at flat index t * n_signals + s
    x = fed.test_x[:5].reshape(5, 64, 6)
    np.testing.assert_array_equal(x[:, 3, 2], fed.test_x[:5][:, 3 * 6 + 2])


def test_road_raw_same_windows_as_feature_path():
    """raw=True must not perturb the RNG draw order: the labels (drawn
    first) of the raw and feature datasets of one seed are identical."""
    _, y_raw, _ = road_like(np.random.default_rng(7), 200, raw=True)
    _, y_feat, _ = road_like(np.random.default_rng(7), 200)
    np.testing.assert_array_equal(y_raw, y_feat)


# ---------------------------------------------------------------------------
# bugfix regressions (ISSUE 4)
# ---------------------------------------------------------------------------


def test_update_k_does_not_shrink_on_round_one():
    """best_metric initialises to +inf; the strong-shrink branch used to
    fire against it (loss < inf is trivially true) and drop K 8→7 with zero
    evidence.  One update from a fresh state must keep K."""
    fl = FLConfig(n_clients=20, clients_per_round=8)
    st = sel_lib.init_k_state(fl)
    st1 = sel_lib.update_k(st, jnp.asarray(0.7, jnp.float32), fl)
    assert float(st1.k) == 8.0
    # ...and the controller still shrinks on GENUINE strong improvement
    st2 = sel_lib.update_k(st1, jnp.asarray(0.3, jnp.float32), fl)
    assert float(st2.k) == 7.0
    # ...and still grows on a plateau
    stp = st1
    for _ in range(int(fl.k_patience)):
        stp = sel_lib.update_k(stp, jnp.asarray(0.7, jnp.float32), fl)
    assert float(stp.k) > 8.0


def test_accountant_q_pinned_to_realised_selection_count():
    """The scheduled path used to feed the accountant q = k_eff/n with the
    controller's FRACTIONAL k while ``_topk_mask`` (ranks < k_eff) selected
    ceil(k_eff) clients — systematic ε under-accounting.  Pin q to the
    realised count for fractional and integer K."""
    n = 20
    avail = jnp.ones((n,), jnp.float32)
    scores = jnp.arange(n, dtype=jnp.float32)
    for k_eff in (7.75, 5.25, 8.0, 1.0):
        mask = sel_lib._topk_mask(scores, avail, jnp.asarray(k_eff), n)
        selected = int(mask.sum())
        assert selected == int(np.ceil(k_eff))
        q = float(realized_cohort_fraction(jnp.asarray(k_eff), n))
        assert q == pytest.approx(selected / n, abs=1e-7)


def test_fractional_q_accounts_more_epsilon():
    """ε composed at the realised ceil(k)/n must exceed the old fractional
    k/n accounting — the fix can only report MORE spend, never less."""
    from repro.privacy import accountant as acct

    n, k_frac, z, rounds, delta = 20, 7.75, 1.5, 50, 1e-5
    eps_old = acct.compose_epsilon(z, k_frac / n, rounds, delta)
    eps_fix = acct.compose_epsilon(
        z, float(realized_cohort_fraction(jnp.asarray(k_frac), n)),
        rounds, delta)
    assert eps_fix > eps_old


# ---------------------------------------------------------------------------
# sequence-model substrate (ISSUE 10)
# ---------------------------------------------------------------------------


def test_sequence_routes_agree_and_loss_is_ref(fed_road):
    """Route contract for the new sequence specs: both routes produce
    logits (ssm's are BITWISE equal — both routes run the same sequential
    scan), and ``loss`` is differentiable (it closes over the ref math;
    a kernel-routed loss would fail here with a missing-VJP error)."""
    meta = meta_for(fed_road, hidden=64)
    x = jnp.asarray(fed_road.test_x[:16])
    y = jnp.asarray(fed_road.test_y[:16])
    for name in ("ssm", "attn", "rglru"):
        spec = get_model_spec(name, meta)
        params = spec.init(jax.random.key(5))
        lk = spec.logits_routed("kernel")(params, x)
        lr = spec.logits_routed("ref")(params, x)
        assert lk.shape == lr.shape == (16, 2)
        if name == "ssm":
            np.testing.assert_array_equal(np.asarray(lk), np.asarray(lr))
        else:
            np.testing.assert_allclose(np.asarray(lk), np.asarray(lr),
                                       atol=1e-4, rtol=1e-4)
        grads = jax.grad(spec.loss)(params, {"x": x, "y": y})
        assert any(float(jnp.abs(g).sum()) > 0
                   for g in jax.tree.leaves(grads))


def test_param_axes_structure_matches_init():
    """The sharding hook's contract: ``param_axes()`` must be a prefix
    tree of ``init``'s params — ``flatten_up_to`` succeeds and yields one
    logical-axes tuple of the right rank per parameter leaf.  And
    ``constrain_params`` outside any shardctx context is the identity
    (same leaves, not copies)."""
    meta = DataMeta(n_features=384, n_classes=2, hidden=64,
                    feature_shape=(64, 6))
    for name in ("ssm", "attn"):
        spec = get_model_spec(name, meta)
        assert spec.param_axes is not None
        params = spec.init(jax.random.key(0))
        treedef = jax.tree.structure(params)
        axes = treedef.flatten_up_to(spec.param_axes())
        leaves = jax.tree.leaves(params)
        assert len(axes) == len(leaves)
        for leaf, ax in zip(leaves, axes):
            assert isinstance(ax, tuple) and len(ax) == leaf.ndim, (name, ax)
        out = spec.constrain_params(params)
        assert all(a is b for a, b in zip(jax.tree.leaves(out), leaves))
    # specs without the hook opt out entirely
    assert get_model_spec("mlp", meta).param_axes is None


def test_model_param_bytes_accounting():
    """``ModelSpec.param_bytes`` equals the actual materialised footprint,
    and ``core/scale.py`` folds per-lane model replicas into the resident
    budget (keyword-defaulted so the PR 6 formulas are unchanged at
    model_bytes=0)."""
    from repro.core import scale as scale_lib

    meta = DataMeta(n_features=384, n_classes=2, hidden=64,
                    feature_shape=(64, 6))
    spec = get_model_spec("ssm", meta)
    real = sum(np.asarray(l).nbytes
               for l in jax.tree.leaves(spec.init(jax.random.key(0))))
    assert spec.param_bytes() == real
    base = scale_lib.population_resident_bytes(1000, 16, n_lanes=3)
    with_model = scale_lib.population_resident_bytes(
        1000, 16, n_lanes=3, model_bytes=real)
    assert with_model == base + 3 * real
    assert not scale_lib.model_needs_sharding(real)   # tiny detector
    assert scale_lib.model_needs_sharding(real, 0)    # forced budget


def test_long_500k_rejects_windowless_attention_arch():
    """ISSUE 10 satellite: the old guard silently resolved a windowless
    attention-family config on ``long_500k`` to ``None`` — full O(L²)
    attention over 524288 positions.  Now a config-build-time ValueError;
    every published arch keeps its declared window."""
    from repro.configs.base import ARCH_IDS, INPUT_SHAPES, get_arch
    from repro.models.model import effective_window

    long = INPUT_SHAPES["long_500k"]
    # every registered arch still resolves (swa variant, sliding window,
    # or an attention-free family)
    for name in ARCH_IDS:
        effective_window(get_arch(name), long)
    # stripping the window declarations from an attention-family arch is
    # now rejected at config-build time instead of lowering full attention
    dense = next(n for n in ARCH_IDS if get_arch(n).family == "dense")
    bad = dataclasses.replace(get_arch(dense), sliding_window=None,
                              long_context_variant=None)
    with pytest.raises(ValueError, match="long_500k"):
        effective_window(bad, long)
    # ssm/hybrid archs are untouched by the guard
    ssm_arch = next(n for n in ARCH_IDS if get_arch(n).family == "ssm")
    assert effective_window(get_arch(ssm_arch), long) is None
    # non-long shapes keep the published attention
    assert effective_window(bad, INPUT_SHAPES["train_4k"]) is None


# ---------------------------------------------------------------------------
# sharded-vs-replicated ssm training (4-faked-device subprocess, ISSUE 10)
# ---------------------------------------------------------------------------

_SSM_SHARD_SCRIPT = r"""
import jax, numpy as np
assert len(jax.devices()) == 4, jax.devices()
from repro.configs.base import FLConfig
from repro.data.synthetic import make_population
from repro.train import fl_driver

pop = make_population(0, dataset="road_raw", n_clients=32, pool_samples=500,
                      members_per_client=16)
fl = FLConfig(n_clients=32, clients_per_round=4, k_max=4, rounds=4,
              local_epochs=2, local_batch=16, model="ssm",
              dp_enabled=False,  # DP noise at this tiny config destabilises
                                 # training and drowns the parity signal
              fault_tolerance=True, failure_prob=0.05)
ref = fl_driver.run_fl_population(pop, fl, seeds=(0, 1), method="random",
                                  rounds=4, eval_every=2,
                                  dataset="road_raw", shard=False)[0]
for shape in [(2, 2), (1, 4)]:
    sh = fl_driver.run_fl_population(
        pop, fl, seeds=(0, 1), method="random", rounds=4, eval_every=2,
        dataset="road_raw", mesh_shape=shape,
        model_replicated_max_bytes=0)[0]   # force the param_axes hook
    for r, s in zip(ref, sh):
        for col in r.history:
            a, b = r.history[col], s.history[col]
            if col == "loss":
                # model math reduces over the sharded tensor-parallel
                # axis -> GSPMD reduction order (measured ~6e-8)
                np.testing.assert_allclose(a, b, atol=1e-5,
                                           err_msg=f"{shape} {col}")
            else:
                # everything else — incl. acc/auc — is bitwise: with
                # stable training the ULP-level gradient drift never
                # flips a prediction, and selection/faults/time never
                # touch the sharded model math under random selection
                assert a == b, (shape, col, a, b)
print("SSM_SHARD_OK")
"""


def test_sharded_ssm_training_matches_replicated(tmp_path):
    """ISSUE 10 parity gate: the ``ssm`` detector trained with its
    parameters tensor-parallel over the client mesh axis (param_axes hook
    forced via ``model_replicated_max_bytes=0``) must reproduce the
    replicated run — selection/fault/time columns bitwise, the
    model-derived scalars within GSPMD reduction-order tolerance.
    Subprocess because the device count must be faked before jax
    initialises (mirrors tests/test_scale.py)."""
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", _SSM_SHARD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SSM_SHARD_OK" in out.stdout
