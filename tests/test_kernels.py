"""Per-kernel validation: shape/dtype sweeps, assert_allclose vs ref.py
(pure-jnp oracle), interpret=True on CPU (assignment deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref as R

KEY = jax.random.key(7)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,hq,hkv,d,causal,window",
    [
        (1, 128, 4, 4, 64, True, None),     # MHA causal
        (2, 256, 8, 2, 64, True, None),     # GQA
        (1, 256, 4, 1, 128, True, 64),      # MQA + sliding window
        (1, 128, 4, 2, 32, False, None),    # bidirectional (encoder)
        (2, 192, 6, 3, 64, True, None),     # non-pow2 seq (block 64)
    ],
)
def test_flash_attention_sweep(b, s, hq, hkv, d, causal, window, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = _rand(k1, (b, s, hq, d), dtype)
    k = _rand(k2, (b, s, hkv, d), dtype)
    v = _rand(k3, (b, s, hkv, d), dtype)
    from repro.kernels.flash_attention import flash_attention

    o = flash_attention(q, k, v, causal=causal, window=window, bq=64, bk=64)
    r = R.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(r, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


# ---------------------------------------------------------------------------
# flash decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,hq,hkv,d,t,length",
    [
        (1, 4, 4, 64, 256, 256),
        (2, 8, 2, 64, 512, 300),   # GQA, partial cache
        (3, 4, 1, 128, 256, 17),   # MQA, short prefix
    ],
)
def test_flash_decode_sweep(b, hq, hkv, d, t, length, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = _rand(k1, (b, hq, d), dtype)
    k = _rand(k2, (b, t, hkv, d), dtype)
    v = _rand(k3, (b, t, hkv, d), dtype)
    o = ops.flash_decode(q, k, v, jnp.full((b,), length))
    r = R.flash_decode_ref(q, k, v, jnp.full((b,), length))
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(r, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


def test_flash_decode_context_parallel_combine():
    """KV-sequence-sharded decode (DESIGN.md §5): shard partials + LSE
    combine must equal the unsharded oracle."""
    b, hq, hkv, d, t, shards = 2, 8, 2, 64, 512, 4
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = _rand(k1, (b, hq, d), jnp.float32)
    k = _rand(k2, (b, t, hkv, d), jnp.float32)
    v = _rand(k3, (b, t, hkv, d), jnp.float32)
    length = jnp.array([300, 512])
    r = R.flash_decode_ref(q, k, v, length)
    per = t // shards
    os_, ms_, ls_ = [], [], []
    for s in range(shards):
        ln = jnp.clip(length - s * per, 0, per)
        o, m, l = ops.flash_decode(q, k[:, s * per:(s + 1) * per],
                                   v[:, s * per:(s + 1) * per], ln,
                                   return_partials=True)
        os_.append(o), ms_.append(m), ls_.append(l)
    oc = ops.combine_decode_partials(jnp.stack(os_), jnp.stack(ms_), jnp.stack(ls_))
    np.testing.assert_allclose(np.asarray(oc), np.asarray(r), atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# dp clip+noise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [64, 1000, 40_000])
@pytest.mark.parametrize("clip,sigma", [(1.0, 0.0), (0.5, 0.1), (100.0, 1.0)])
def test_dp_clip_noise_sweep(n, clip, sigma):
    k1, k2 = jax.random.split(KEY)
    x = _rand(k1, (n,), jnp.float32) * 3
    nz = _rand(k2, (n,), jnp.float32)
    o, norm = ops.dp_clip_noise(x, nz, clip, sigma)
    r = R.dp_clip_noise_ref(x, nz, clip, sigma)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(float(norm), float(jnp.linalg.norm(x)), rtol=1e-5)


def test_dp_clip_noise_tree_matches_core_dp():
    """Kernel tree path must match core.dp clipping semantics exactly when
    noise is disabled (sigma=0)."""
    from repro.core import dp as dpc

    k1, k2 = jax.random.split(KEY)
    tree = {"a": _rand(k1, (33, 17), jnp.float32) * 5,
            "b": [_rand(k2, (11,), jnp.float32)]}
    noised, norm = ops.dp_clip_noise_tree(tree, KEY, clip=1.0, sigma=0.0,
                                          interpret=True)
    expected, norm2 = dpc.clip_by_global_norm(tree, 1.0)
    np.testing.assert_allclose(float(norm), float(norm2), rtol=1e-6)
    for a, b in zip(jax.tree.leaves(noised), jax.tree.leaves(expected)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_dp_clip_noise_tree_pallas_matches_ref_fallback():
    """The kernel path (interpret-mode Pallas) and the kernels.ref fallback
    the CPU aggregation path uses must agree including the NOISE (identical
    key-split order), so routing by backend never changes semantics."""
    k1, k2 = jax.random.split(KEY)
    tree = {"a": _rand(k1, (19, 7), jnp.float32) * 4,
            "b": [_rand(k2, (257,), jnp.float32)]}
    kern, n1 = ops.dp_clip_noise_tree(tree, KEY, clip=0.8, sigma=0.3,
                                      interpret=True)
    ref, n2 = R.dp_clip_noise_tree_ref(tree, KEY, clip=0.8, sigma=0.3)
    np.testing.assert_allclose(float(n1), float(n2), rtol=1e-6)
    for a, b in zip(jax.tree.leaves(kern), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ---------------------------------------------------------------------------
# rglru scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,l,w,chunk,h0",
    [
        (1, 128, 128, 64, False),
        (2, 256, 96, 128, True),
        (1, 64, 512, 32, True),
        (3, 128, 64, 128, False),  # chunk == l
    ],
)
def test_rglru_scan_sweep(b, l, w, chunk, h0):
    k1, k2, k3 = jax.random.split(KEY, 3)
    a = jax.nn.sigmoid(_rand(k1, (b, l, w), jnp.float32))
    x = _rand(k2, (b, l, w), jnp.float32)
    h0v = _rand(k3, (b, w), jnp.float32) if h0 else None
    h, hl = ops.rglru_scan(a, x, h0v)
    rh, rhl = R.rglru_scan_ref(a, x, h0v)
    np.testing.assert_allclose(np.asarray(h), np.asarray(rh), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(rhl), atol=1e-4, rtol=1e-4)


def test_rglru_matches_associative_scan_in_model():
    """Model-level: kernel path == associative-scan path."""
    from repro.models.rglru import rglru_scan as model_scan

    k1, k2 = jax.random.split(KEY)
    a = jax.nn.sigmoid(_rand(k1, (2, 128, 64), jnp.float32))
    x = _rand(k2, (2, 128, 64), jnp.float32)
    h1, _ = model_scan(a, x)
    h2, _ = ops.rglru_scan(a, x)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4)


@pytest.mark.parametrize(
    "b,l,w,h0",
    [
        (1, 128, 128, False),
        (2, 64, 96, True),
        (3, 64, 512, True),    # the ssm detector's flattened-state width
        (1, 4, 512, False),    # few steps, wide lanes (chunk-state shape)
    ],
)
def test_rglru_scan_pallas_bitwise_vs_ref(b, l, w, h0):
    """ISSUE 10 kernel-parity pin: the Pallas chunked scan and the
    sequential ``kernels.ref`` oracle run the SAME f32 ``h = a·h + x``
    recurrence in the same order, so the two score routes of the sequence
    detectors are BITWISE equal on the forward pass — not merely close."""
    k1, k2, k3 = jax.random.split(KEY, 3)
    a = jax.nn.sigmoid(_rand(k1, (b, l, w), jnp.float32))
    x = _rand(k2, (b, l, w), jnp.float32)
    h0v = _rand(k3, (b, w), jnp.float32) if h0 else None
    h, hl = ops.rglru_scan(a, x, h0v)
    rh, rhl = R.rglru_scan_ref(a, x, h0v)
    assert np.array_equal(np.asarray(h), np.asarray(rh))
    assert np.array_equal(np.asarray(hl), np.asarray(rhl))


def test_rglru_scan_interpret_auto_resolve():
    """``interpret=None`` resolves by backend like the flash kernels: on
    CPU it must take the interpret-mode path (and agree with an explicit
    interpret=True bitwise) instead of trying to compile Pallas TPU code."""
    k1, k2 = jax.random.split(KEY)
    a = jax.nn.sigmoid(_rand(k1, (2, 64, 64), jnp.float32))
    x = _rand(k2, (2, 64, 64), jnp.float32)
    h_auto, hl_auto = ops.rglru_scan(a, x)                  # interpret=None
    h_exp, hl_exp = ops.rglru_scan(a, x, interpret=True)
    assert np.array_equal(np.asarray(h_auto), np.asarray(h_exp))
    assert np.array_equal(np.asarray(hl_auto), np.asarray(hl_exp))
