"""Launch-layer tests: collective-bytes HLO parsing, sharding rule tables,
spec sanitisation, and a subprocess mini dry-run (lower+compile on forced
host devices) so the multi-pod path is exercised inside the test suite."""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.models.sharding import (logical_to_pspec, make_rules, sanitize_pspec)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# HLO collective parser
# ---------------------------------------------------------------------------


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes

    hlo = """
  %all-reduce.1 = f32[1024,16]{1,0} all-reduce(f32[1024,16]{1,0} %x), replica_groups={}
  %ag = bf16[512]{0} all-gather(bf16[256]{0} %y), dimensions={0}
  ROOT %rs = f32[128]{0} reduce-scatter(f32[1024]{0} %z), dimensions={0}
  %a2a = (f32[64]{0}, f32[64]{0}) all-to-all(f32[64]{0} %p, f32[64]{0} %q)
  %cp-start = bf16[32]{0} collective-permute-start(bf16[32]{0} %w)
  %not-a-collective = f32[99]{0} add(f32[99]{0} %a, f32[99]{0} %b)
"""
    c = collective_bytes(hlo)
    assert c["all-reduce"] == 1024 * 16 * 4
    assert c["all-gather"] == 512 * 2
    assert c["reduce-scatter"] == 128 * 4
    assert c["all-to-all"] == 64 * 4 * 2
    assert c["total"] == sum(
        c[k] for k in ("all-reduce", "all-gather", "reduce-scatter",
                       "all-to-all", "collective-permute")
    )
    assert c["counts"]["all-reduce"] == 1


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------


def test_rules_client_parallel_never_shards_weights_over_data():
    rules = make_rules("client_parallel", multi_pod=False)
    assert rules["embed"] is None  # per-client weights diverge
    assert rules["mlp"] == ("model",)


def test_rules_client_serial_fsdp():
    rules = make_rules("client_serial", multi_pod=True)
    assert rules["embed"] == ("pod", "data")
    assert rules["act_batch"] == ("pod", "data")


def test_logical_to_pspec_dedupes_axes():
    rules = {"embed": ("data",), "mlp": ("model",), "vocab": ("model",)}
    spec = logical_to_pspec(("embed", "mlp"), rules)
    assert spec == P("data", "model")
    # same mesh axis twice: second occurrence dropped
    spec2 = logical_to_pspec(("mlp", "vocab"), rules)
    assert spec2 == P("model")


def test_sanitize_pspec_drops_indivisible():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # kv=8 over model of size 1 is fine; fake a 16-sized mesh via np mesh
    devs = np.array(jax.devices() * 16)[:16].reshape(4, 4) if False else None
    mesh4 = jax.make_mesh((1, 1), ("data", "model"))
    spec = sanitize_pspec((8, 4), P("data", "model"), mesh4)
    assert spec == P("data", "model")  # everything divides by 1


def test_input_specs_cover_all_modes():
    from repro.configs.base import ARCH_IDS, INPUT_SHAPES, get_arch, get_shape
    from repro.models.model import build

    for arch in ARCH_IDS:
        cfg = get_arch(arch, smoke=True)
        model = build(cfg)
        for sname in INPUT_SHAPES:
            shape = get_shape(sname)
            # reduce the shape so cache spec construction stays tiny
            import dataclasses

            small = dataclasses.replace(shape, seq_len=64, global_batch=2)
            specs = model.input_specs(small)
            leaves = jax.tree.leaves(specs)
            assert leaves, (arch, sname)
            for l in leaves:
                assert hasattr(l, "shape") and hasattr(l, "dtype")


# ---------------------------------------------------------------------------
# Mini dry-run in a subprocess (8 forced host devices, 2x2x2 mesh)
# ---------------------------------------------------------------------------

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, json
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
import numpy as np

from repro.configs.base import MeshConfig, ShapeConfig, get_arch
from repro.launch import steps as steps_lib

# miniature "pods": 2x2x2
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
mesh_cfg = MeshConfig(multi_pod=True)
object.__setattr__  # frozen dataclass: build shapes directly
shape = ShapeConfig("train_4k", 64, 8, "train")
cfg = get_arch("ARCH", smoke=True)
bundle = steps_lib.build_step(cfg, shape, mesh_cfg, mesh)
with mesh:
    jitted = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                     out_shardings=bundle.out_shardings)
    lowered = jitted.lower(*bundle.in_specs)
    compiled = lowered.compile()
cost = compiled.cost_analysis()
if isinstance(cost, (list, tuple)):  # older jax returns [dict]
    cost = cost[0]
print(json.dumps({"ok": True, "flops": cost.get("flops", -1.0)}))
"""


@pytest.mark.parametrize("arch", ["mamba2_130m", "phi3p5_moe_42b"])
def test_mini_dryrun_subprocess(arch):
    """lower+compile an FL train round on a 2x2x2 placeholder multi-pod mesh
    (smoke-scale twin of the 2x16x16 production dry-run)."""
    code = _SUBPROC.replace("ARCH", arch)
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    last = out.stdout.strip().splitlines()[-1]
    assert json.loads(last)["ok"]


def test_decode_mini_dryrun_subprocess():
    code = _SUBPROC.replace("ARCH", "recurrentgemma_9b").replace(
        'ShapeConfig("train_4k", 64, 8, "train")',
        'ShapeConfig("decode_32k", 128, 8, "decode")',
    )
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    assert json.loads(out.stdout.strip().splitlines()[-1])["ok"]
