"""Sweep-engine tests (ISSUE 2): the seed×config lane axis must be a
drop-in replacement for running grid cells one at a time.

* ``run_fl_sweep`` over a stacked ε grid matches per-cell ``run_fl`` lane
  for lane — same seeds, same eval history, same reported ε;
* the compiled-runner cache keys on STATICS + shapes: one ``_get_runner``
  miss per shape, zero new misses when only runtime knobs change;
* static-field mismatches inside a grid are rejected loudly;
* ``make_serial_round`` honours ``ckpt_every_steps`` (it used to hardcode 2);
* the lane axis shards over a multi-device mesh without changing results
  (subprocess with XLA_FLAGS-faked CPU devices).
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (FLConfig, FLParams, fl_params, fl_static)
from repro.core import rounds as rounds_lib
from repro.data.synthetic import make_federated, round_batches
from repro.models import mlp as mlp_lib
from repro.train import fl_driver

ROUNDS = 12
EVAL_EVERY = 5
SEEDS = (0, 2)


@pytest.fixture(scope="module")
def fed():
    return make_federated(0, "unsw", n_samples=1_500, n_clients=8)


@pytest.fixture(scope="module")
def fl():
    return FLConfig(n_clients=8, clients_per_round=3, rounds=ROUNDS,
                    local_epochs=2, local_batch=16, local_lr=0.08,
                    dp_enabled=True, dp_mode="clipped", dp_epsilon=200.0,
                    dp_clip=5.0, fault_tolerance=True, failure_prob=0.05)


# ---------------------------------------------------------------------------
# static/runtime split
# ---------------------------------------------------------------------------


def test_fl_static_collapses_runtime_fields(fl):
    a = dataclasses.replace(fl, dp_epsilon=0.1, failure_prob=0.4,
                            local_lr=0.01, server_lr=0.5, explore_noise=0.2)
    assert fl_static(a) == fl_static(fl)
    b = dataclasses.replace(fl, selection="random")  # static: new program
    assert fl_static(b) != fl_static(fl)


def test_fl_params_mirrors_config(fl):
    pr = fl_params(dataclasses.replace(fl, dp_epsilon=3.5, k_patience=7.0))
    assert pr.dp_epsilon == 3.5
    assert pr.k_patience == 7.0
    # FLParams is a flat pytree of scalars — vmappable lane material
    leaves = jax.tree.leaves(pr)
    assert len(leaves) == len(FLParams._fields)


# ---------------------------------------------------------------------------
# sweep vs per-cell equivalence
# ---------------------------------------------------------------------------


def test_sweep_matches_per_cell_lane_for_lane(fed, fl):
    """A stacked ε grid must reproduce per-cell ``run_fl`` exactly (same
    seeds, same eval history, same reported ε) — the sweep lane axis is pure
    throughput, never semantics."""
    epsilons = (50.0, 200.0, 1000.0)
    cells = [dataclasses.replace(fl, dp_epsilon=e) for e in epsilons]
    sweep = fl_driver.run_fl_sweep(fed, fl, cells, seeds=SEEDS,
                                   rounds=ROUNDS, eval_every=EVAL_EVERY)
    assert len(sweep) == len(cells) and all(len(r) == len(SEEDS) for r in sweep)
    for cell, row in zip(cells, sweep):
        for seed, lane in zip(SEEDS, row):
            single = fl_driver.run_fl(fed, cell, "proposed", seed=seed,
                                      rounds=ROUNDS, eval_every=EVAL_EVERY)
            assert lane.seed == seed
            assert lane.eps_spent == single.eps_spent
            assert lane.history["round"] == single.history["round"]
            np.testing.assert_allclose(lane.accuracy, single.accuracy,
                                       atol=1e-5)
            np.testing.assert_allclose(lane.history["acc"],
                                       single.history["acc"], atol=1e-5)
            np.testing.assert_allclose(lane.history["cum_time"],
                                       single.history["cum_time"], rtol=1e-5)
    # ε must actually differ across cells (the grid is real, not broadcast)
    eps = [row[0].eps_spent for row in sweep]
    assert eps == sorted(eps) and len(set(eps)) == len(cells)


def test_one_compile_per_shape_not_per_cell(fed, fl):
    """The whole point of the runtime-parameter engine: a grid compiles
    once.  New runtime values -> cache hit; new lane count or statics ->
    miss."""
    epsilons = (60.0, 120.0, 240.0, 480.0)
    cells = [dataclasses.replace(fl, dp_epsilon=e) for e in epsilons]
    m0 = fl_driver.RUNNER_STATS["misses"]
    fl_driver.run_fl_sweep(fed, fl, cells, seeds=SEEDS, rounds=ROUNDS,
                           eval_every=EVAL_EVERY)
    assert fl_driver.RUNNER_STATS["misses"] - m0 <= 1  # one program, whole grid

    # per-cell batches with DIFFERENT runtime values reuse one program too
    fl_driver.run_fl_batch(fed, cells[0], seeds=SEEDS, rounds=ROUNDS,
                           eval_every=EVAL_EVERY)
    m1 = fl_driver.RUNNER_STATS["misses"]
    for cell in cells[1:]:
        fl_driver.run_fl_batch(fed, cell, seeds=SEEDS, rounds=ROUNDS,
                               eval_every=EVAL_EVERY)
    assert fl_driver.RUNNER_STATS["misses"] == m1, \
        "runtime-only config change must not recompile"

    # a STATIC change does compile a new program
    fl_driver.run_fl_batch(fed, dataclasses.replace(fl, selection="random"),
                           method="random", seeds=SEEDS, rounds=ROUNDS,
                           eval_every=EVAL_EVERY)
    assert fl_driver.RUNNER_STATS["misses"] == m1 + 1


def test_sweep_rejects_static_mismatch(fed, fl):
    # dp_mode gates code structure (and survives fl_for_method, which owns
    # the selection field) — it cannot ride the runtime lane axis
    bad = dataclasses.replace(fl, dp_mode="paper")
    with pytest.raises(ValueError, match="STATIC"):
        fl_driver.run_fl_sweep(fed, fl, [fl, bad], seeds=(0,), rounds=4)


def test_sweep_accepts_dict_and_flparams_cells(fed, fl):
    grid = [{"dp_epsilon": 80.0},
            fl_params(dataclasses.replace(fl, dp_epsilon=80.0))]
    res = fl_driver.run_fl_sweep(fed, fl, grid, seeds=(0,), rounds=6,
                                 eval_every=3)
    # both spellings denote the same cell -> identical lanes
    assert res[0][0].eps_spent == res[1][0].eps_spent
    np.testing.assert_allclose(res[0][0].accuracy, res[1][0].accuracy,
                               atol=1e-6)


def test_runtime_params_change_results(fed, fl):
    """The runtime lane values must actually reach the math: crank the DP
    noise (tiny ε) and training must degrade relative to near-noiseless."""
    cells = [dataclasses.replace(fl, dp_epsilon=0.05),
             dataclasses.replace(fl, dp_epsilon=5000.0)]
    sweep = fl_driver.run_fl_sweep(fed, fl, cells, seeds=(0, 1, 2),
                                   rounds=ROUNDS, eval_every=ROUNDS)
    # same seed, different ε lane -> the trajectories MUST diverge (guards
    # against a regression that silently drops the runtime value)
    for lane_noisy, lane_clean in zip(sweep[0], sweep[1]):
        assert lane_noisy.history["loss"] != lane_clean.history["loss"]
    noisy = np.mean([r.accuracy for r in sweep[0]])
    clean = np.mean([r.accuracy for r in sweep[1]])
    assert clean > noisy - 0.02, (clean, noisy)
    # ...and the selection temperature reaches the strategy: an absurd
    # temperature makes selection ~random, changing the trajectory
    hot = fl_driver.run_fl_sweep(fed, fl, [{"explore_noise": 50.0}],
                                 seeds=(0,), rounds=ROUNDS,
                                 eval_every=ROUNDS)[0][0]
    cold = fl_driver.run_fl_sweep(fed, fl, [{"explore_noise": 0.0}],
                                  seeds=(0,), rounds=ROUNDS,
                                  eval_every=ROUNDS)[0][0]
    assert hot.history["loss"] != cold.history["loss"]


# ---------------------------------------------------------------------------
# serial plan: ckpt_every_steps is configurable (was hardcoded to 2)
# ---------------------------------------------------------------------------


def test_serial_round_respects_ckpt_every(fed):
    """With p_fail=1 and ckpt interval == local_steps, every failing client
    loses ALL work (no checkpoint before the failure step) -> params frozen;
    with interval 1 the failure step itself is the checkpoint -> progress.
    The old hardcoded interval of 2 made both behave alike."""
    def run(ckpt_every):
        flc = FLConfig(n_clients=6, clients_per_round=4, adaptive_k=False,
                       local_epochs=1, local_batch=16, local_lr=0.1,
                       dp_enabled=False, fault_tolerance=True,
                       failure_prob=1.0, serial_clients_in_step=3)
        params = mlp_lib.init_mlp(jax.random.key(0), fed.n_features, 16, 2)
        state = rounds_lib.init_round_state(params, flc, jax.random.key(1),
                                            n_clients=6)
        step = jax.jit(rounds_lib.make_serial_round(
            mlp_lib.mlp_loss, flc, 6, ckpt_every_steps=ckpt_every))
        rng = np.random.default_rng(0)
        b = jax.tree.map(jnp.asarray, round_batches(rng, fed, 4, 16))
        state, _ = step(state, jax.tree.map(lambda x: x[:3], b))
        return state.params

    p0 = mlp_lib.init_mlp(jax.random.key(0), fed.n_features, 16, 2)
    frozen = run(ckpt_every=4)     # kept = (fail//4)*4 = 0 for fail in [0,4)
    for a, b in zip(jax.tree.leaves(frozen), jax.tree.leaves(p0)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    moved = run(ckpt_every=1)      # kept = fail step itself
    assert any(bool(jnp.any(a != b)) for a, b in
               zip(jax.tree.leaves(moved), jax.tree.leaves(p0)))


# ---------------------------------------------------------------------------
# mesh sharding of the lane axis
# ---------------------------------------------------------------------------

_SHARD_SCRIPT = r"""
import dataclasses, jax, numpy as np
assert len(jax.devices()) == 4, jax.devices()
from repro.configs.base import FLConfig
from repro.data.synthetic import make_federated
from repro.train import fl_driver

fed = make_federated(0, "unsw", n_samples=800, n_clients=6)
fl = FLConfig(n_clients=6, clients_per_round=3, rounds=6, local_epochs=2,
              local_batch=16, dp_enabled=True, dp_mode="clipped",
              dp_epsilon=300.0, dp_clip=5.0, fault_tolerance=True)
cells = [dataclasses.replace(fl, dp_epsilon=e) for e in (100.0, 300.0)]
sweep = fl_driver.run_fl_sweep(fed, fl, cells, seeds=(0, 1), rounds=6,
                               eval_every=3)   # 4 lanes over 4 devices
ref = fl_driver.run_fl(fed, cells[0], seed=1, rounds=6, eval_every=3)
np.testing.assert_allclose(sweep[0][1].accuracy, ref.accuracy, atol=1e-5)
np.testing.assert_allclose(sweep[0][1].history["acc"], ref.history["acc"],
                           atol=1e-5)
assert all(np.isfinite(r.sim_time_s) for row in sweep for r in row)
print("SHARDED_SWEEP_OK")
"""


def test_lane_axis_shards_over_device_mesh(tmp_path):
    """4 lanes over 4 (XLA-faked) CPU devices: the NamedSharding path must
    produce the same per-lane results as the single-device engine.  Runs in
    a subprocess because the device count must be set before jax init."""
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SHARDED_SWEEP_OK" in out.stdout
