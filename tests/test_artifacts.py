"""Dry-run artifact coverage: every assigned (arch × shape × mesh) baseline
must exist, parse, and carry the fields the roofline analysis needs.

(The artifacts are produced by `python -m repro.launch.dryrun --all --mesh
both`; this test guards against silently losing coverage.  It SKIPS — not
fails — when the sweep has never been run, e.g. on a fresh checkout.)
"""
import glob
import json
import os

import pytest

from repro.configs.base import ARCH_IDS, INPUT_SHAPES

ART = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "artifacts")


def _have_any():
    return bool(glob.glob(os.path.join(ART, "*__single.json")))


@pytest.mark.skipif(
    not _have_any(),
    reason="dry-run sweep artifacts absent (generate with: python -m "
           "repro.launch.dryrun --all --mesh both)")
@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_all_40_pairs_have_baseline_artifacts(mesh):
    missing = []
    for arch in ARCH_IDS:
        for shape in INPUT_SHAPES:
            p = os.path.join(ART, f"{arch}__{shape}__{mesh}.json")
            if not os.path.exists(p):
                missing.append((arch, shape))
    assert not missing, f"missing {mesh} baselines: {missing}"


@pytest.mark.skipif(
    not _have_any(),
    reason="dry-run sweep artifacts absent (generate with: python -m "
           "repro.launch.dryrun --all --mesh both)")
def test_artifacts_carry_roofline_fields():
    for p in glob.glob(os.path.join(ART, "*__single.json")):
        with open(p) as f:
            a = json.load(f)
        if "arch" not in a:  # fl_results.json etc.
            continue
        assert a["cost"]["flops"] >= 0, p
        assert a["cost"]["bytes_accessed"] >= 0, p
        assert "total" in a["collectives"], p
        assert a["devices"] in (256, 512), p
        assert a["memory"]["temp_bytes"] is not None, p


@pytest.mark.skipif(
    not _have_any(),
    reason="dry-run sweep artifacts absent (generate with: python -m "
           "repro.launch.dryrun --all --mesh both)")
def test_hillclimb_winner_artifacts_exist():
    """The §Perf optimized variants referenced by EXPERIMENTS.md."""
    for tag_file in (
        "mamba2_130m__decode_32k__single__ssmstate.json",
        "llama4_maverick_400b__train_4k__single__scatter.json",
        "mistral_large_123b__train_4k__single__seqpar.json",
    ):
        assert os.path.exists(os.path.join(ART, tag_file)), tag_file
