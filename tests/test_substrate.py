"""Substrate tests: data generators, optimizers, checkpointing, MoE
implementations, remat grouping, model consistency extras."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, get_arch
from repro.data.synthetic import (dirichlet_partition, make_federated,
                                  road_like, unsw_nb15_like)
from repro.data.tokens import ZipfMarkovStream, lm_round_batches


# ---------------------------------------------------------------------------
# Data
# ---------------------------------------------------------------------------


def test_unsw_like_schema():
    rng = np.random.default_rng(0)
    X, y_cat, y_bin = unsw_nb15_like(rng, 5000)
    assert X.shape == (5000, 42)
    assert y_cat.max() <= 9 and y_cat.min() >= 0
    # heavy class imbalance: mostly normal traffic
    assert 0.8 < float((y_cat == 0).mean()) < 0.95
    assert np.isfinite(X).all()
    # standardised
    np.testing.assert_allclose(X.mean(0), 0, atol=1e-5)
    np.testing.assert_allclose(X.std(0), 1, atol=1e-3)


def test_road_like_attacks_are_detectable_but_subtle():
    rng = np.random.default_rng(0)
    X, y, _ = road_like(rng, 400)
    assert X.shape[1] == 30
    assert 0.1 < y.mean() < 0.4
    # masquerade should shift the cross-correlation features measurably
    pos, neg = X[y == 1], X[y == 0]
    d = np.abs(pos.mean(0) - neg.mean(0))
    assert d.max() > 0.1, "attacks statistically invisible"


def test_dirichlet_partition_covers_all_and_respects_minimum():
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 4, 2000)
    parts = dirichlet_partition(rng, labels, 10, alpha=0.3, min_per_client=8)
    assert len(parts) == 10
    assert all(len(p) >= 8 for p in parts)
    covered = np.concatenate(parts)
    assert len(np.unique(covered)) > 1900  # near-total coverage


def test_federated_metadata():
    fed = make_federated(0, "unsw", n_samples=2000, n_clients=8)
    assert fed.n_clients == 8
    assert (fed.data_sizes() > 0).all()
    ent = fed.label_entropy()
    assert ((ent >= 0) & (ent <= 1.0)).all()


def test_zipf_markov_stream_is_deterministic_and_skewed():
    s1 = ZipfMarkovStream(1000, seed=7).sample(4, 64)
    s2 = ZipfMarkovStream(1000, seed=7).sample(4, 64)
    np.testing.assert_array_equal(s1, s2)
    # zipf skew: low token ids should dominate
    assert (s1 < 100).mean() > 0.4
    b = lm_round_batches(500, 3, 2, 2, 16, seed=1)
    np.testing.assert_array_equal(b["tokens"][..., 1:], b["labels"][..., :-1])


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------


def test_sgd_momentum_and_adam_descend_quadratic():
    from repro.optim.optimizers import adam, sgd

    target = jnp.array([3.0, -2.0])

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for opt in (sgd(0.1), sgd(0.05, momentum=0.9), adam(0.1)):
        p = {"w": jnp.zeros(2)}
        state = opt.init(p)
        for _ in range(200):
            g = jax.grad(loss)(p)
            p, state = opt.update(g, state, p)
        assert float(loss(p)) < 1e-2, opt.name


def test_server_fedavg_is_plus_delta():
    from repro.optim.optimizers import make_server_optimizer

    srv = make_server_optimizer("sgd", 1.0)
    p = {"w": jnp.ones(3)}
    delta = {"w": jnp.array([0.5, -0.5, 1.0])}
    new_p, _ = srv.update(delta, srv.init(p), p)
    np.testing.assert_allclose(np.asarray(new_p["w"]), [1.5, 0.5, 2.0])


def test_fedadam_state_advances():
    from repro.optim.optimizers import make_server_optimizer

    srv = make_server_optimizer("fedadam", 0.1)
    p = {"w": jnp.ones(3)}
    st = srv.init(p)
    new_p, st2 = srv.update({"w": jnp.ones(3)}, st, p)
    assert int(st2.count) == 1
    assert not np.allclose(np.asarray(new_p["w"]), 1.0)


# ---------------------------------------------------------------------------
# MoE implementations
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("epk", [1, 2])
def test_moe_scatter_matches_einsum(epk):
    from repro.models import moe as M

    cfg = ModelConfig(name="m", family="moe", n_layers=2, d_model=32, n_heads=4,
                      n_kv_heads=2, d_ff=64, vocab_size=100, n_experts=4,
                      experts_per_token=epk, capacity_factor=1.25)
    meta = M.init_moe(jax.random.key(0), cfg)
    params = jax.tree.map(lambda m: m.value, meta, is_leaf=lambda x: hasattr(x, "axes"))
    x = jax.random.normal(jax.random.key(1), (2, 16, 32))
    y1, a1 = M.moe_mlp(params, x, cfg, impl="einsum")
    y2, a2 = M.moe_mlp(params, x, cfg, impl="scatter")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)


def test_moe_capacity_drops_tokens():
    """With tiny capacity most tokens must be dropped (output ~ 0 for them)."""
    from repro.models import moe as M

    cfg = ModelConfig(name="m", family="moe", n_layers=1, d_model=16, n_heads=2,
                      n_kv_heads=1, d_ff=32, vocab_size=10, n_experts=2,
                      experts_per_token=1, capacity_factor=0.25)
    meta = M.init_moe(jax.random.key(0), cfg)
    params = jax.tree.map(lambda m: m.value, meta, is_leaf=lambda x: hasattr(x, "axes"))
    x = jax.random.normal(jax.random.key(1), (1, 32, 16))
    dispatch, combine, _ = M.route(params["router"], x, cfg)
    kept = float(jnp.sum(dispatch))
    assert kept <= M._capacity(cfg, 32) * cfg.n_experts + 1e-6


# ---------------------------------------------------------------------------
# Remat grouping (perf feature) must not change math
# ---------------------------------------------------------------------------


def test_remat_group_grad_equivalence():
    from repro.models.model import build

    cfg = dataclasses.replace(get_arch("qwen2p5_32b", smoke=True), n_layers=2)
    m = build(cfg)
    params = m.init(jax.random.key(0))
    b = {"tokens": jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size),
         "labels": jax.random.randint(jax.random.key(2), (2, 16), 0, cfg.vocab_size)}
    l1, g1 = jax.value_and_grad(lambda p: m.loss(p, b, remat_group=1))(params)
    l2, g2 = jax.value_and_grad(lambda p: m.loss(p, b, remat_group=2))(params)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
    for a, c in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32), atol=1e-5)


# ---------------------------------------------------------------------------
# Sliding-window semantics (long_500k variant correctness)
# ---------------------------------------------------------------------------


def test_rolling_cache_matches_full_cache_within_window():
    """SWA decode with a rolling cache must equal full-cache attention once
    both see exactly the last `window` tokens."""
    from repro.models.model import build

    cfg = get_arch("granite_3_8b", smoke=True)
    window = 8
    m = build(cfg)
    params = m.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(3), (1, 24), 0, cfg.vocab_size)

    # full forward with window mask (oracle)
    full = m.forward(params, {"tokens": toks}, window=window)

    # stepwise with rolling cache of exactly `window` slots
    caches = m.init_cache(1, 24, window=window)
    outs = []
    for t in range(24):
        lg, caches = m.decode_step(params, toks[:, t:t + 1], caches,
                                   jnp.asarray(t), window=window)
        outs.append(lg)
    stepwise = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full, np.float32),
                               np.asarray(stepwise, np.float32),
                               atol=2e-2, rtol=2e-2)


# ---------------------------------------------------------------------------
# Chunked SSD vs the naive per-timestep recurrence (ISSUE 10)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # local containers without the wheel: seeded sweeps
    HAVE_HYPOTHESIS = False

from repro.models import ssm as ssm_lib


def _ssd_naive(x, dt, A, B, C, s0=None):
    """The O(L)-step recurrent oracle (ssd_decode_step's math, batched):
    S_t = exp(-A dt_t)·S_{t-1} + dt_t·(x_t ⊗ B_t);  y_t = C_t · S_t."""
    b, l, h, p = x.shape
    n = B.shape[-1]
    s = jnp.zeros((b, h, p, n), jnp.float32) if s0 is None else s0
    ys = []
    for t in range(l):
        dA = jnp.exp(-A * dt[:, t])  # [b, h]
        s = s * dA[:, :, None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dt[:, t], x[:, t], B[:, t])
        ys.append(jnp.einsum("bn,bhpn->bhp", C[:, t], s))
    return jnp.stack(ys, axis=1), s


def _ssd_case(rng):
    """Random dims exercising chunk boundaries: l is a multiple of several
    candidate chunk sizes, so chunk ∈ {1 (pure recurrence), l (pure
    quadratic), divisors in between (boundary crossings)}."""
    b = int(rng.integers(1, 3))
    l = int(rng.choice([4, 8, 12, 16]))
    h = int(rng.integers(1, 3))
    p = int(rng.integers(1, 5))
    n = int(rng.integers(1, 5))
    divs = [q for q in (1, 2, 3, 4, 6, 8, 12, 16) if l % q == 0]
    chunk = int(rng.choice(divs))
    with_state = bool(rng.random() < 0.5)
    seed = int(rng.integers(0, 2**31 - 1))
    return b, l, h, p, n, chunk, with_state, seed


def _check_ssd_chunked_case(b, l, h, p, n, chunk, with_state, seed):
    keys = jax.random.split(jax.random.key(seed), 6)
    x = jax.random.normal(keys[0], (b, l, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(keys[1], (b, l, h), jnp.float32))
    A = jnp.exp(jax.random.uniform(keys[2], (h,), jnp.float32,
                                   minval=0.0, maxval=2.0))
    B = jax.random.normal(keys[3], (b, l, n), jnp.float32)
    C = jax.random.normal(keys[4], (b, l, n), jnp.float32)
    s0 = (jax.random.normal(keys[5], (b, h, p, n), jnp.float32)
          if with_state else None)
    y, fin = ssm_lib.ssd_chunked(x, dt, A, B, C, chunk, s0)
    ry, rfin = _ssd_naive(x, dt, A, B, C, s0)
    # quadratic masked form vs sequential recurrence: same math, different
    # association — tight allclose, not bitwise
    np.testing.assert_allclose(np.asarray(y), np.asarray(ry),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(rfin),
                               atol=2e-4, rtol=2e-4)


if HAVE_HYPOTHESIS:

    @settings(deadline=None, max_examples=40)
    @given(st.integers(0, 2**31 - 1))
    def test_ssd_chunked_matches_naive_recurrence(case_seed):
        _check_ssd_chunked_case(
            *_ssd_case(np.random.default_rng(case_seed)))

else:

    def test_ssd_chunked_matches_naive_recurrence():
        rng = np.random.default_rng(0)
        for _ in range(40):
            _check_ssd_chunked_case(*_ssd_case(rng))


def test_ssd_chunked_chunk_boundary_and_init_state_pins():
    """Deterministic pins for the cases that have regressed elsewhere in
    the literature: a chunk boundary mid-sequence (inter-chunk recurrence
    must carry decayed state) and a nonzero init_state entering chunk 0."""
    for chunk, with_state in [(4, False), (4, True), (1, True), (16, True)]:
        _check_ssd_chunked_case(2, 16, 2, 3, 4, chunk, with_state, seed=123)


def test_ssd_chunked_routed_scan_fn_bitwise():
    """The routed inter-chunk recurrence (``chunk_scan_via`` over the
    rglru_scan kernel/ref primitives — the ssm detector's two score
    routes) must be BITWISE equal to the inline ``lax.scan`` it replaces:
    same sequential f32 ``s = dec·s + st``, only the carrier differs."""
    from repro.kernels import ops as kops
    from repro.kernels import ref as kref

    keys = jax.random.split(jax.random.key(11), 6)
    b, l, h, p, n, chunk = 2, 16, 2, 4, 4, 4
    x = jax.random.normal(keys[0], (b, l, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(keys[1], (b, l, h), jnp.float32))
    A = jnp.exp(jax.random.uniform(keys[2], (h,), jnp.float32, maxval=2.0))
    B = jax.random.normal(keys[3], (b, l, n), jnp.float32)
    C = jax.random.normal(keys[4], (b, l, n), jnp.float32)
    s0 = jax.random.normal(keys[5], (b, h, p, n), jnp.float32)
    y0, f0 = ssm_lib.ssd_chunked(x, dt, A, B, C, chunk, s0)
    for prim in (kref.rglru_scan_ref, kops.rglru_scan):
        y1, f1 = ssm_lib.ssd_chunked(x, dt, A, B, C, chunk, s0,
                                     scan_fn=ssm_lib.chunk_scan_via(prim))
        assert np.array_equal(np.asarray(y0), np.asarray(y1))
        assert np.array_equal(np.asarray(f0), np.asarray(f1))
