"""Per-architecture smoke tests (assignment requirement).

For each of the 10 assigned architectures: instantiate the REDUCED variant
of the same family (<=2 layers, d_model<=512, <=4 experts), run one forward
pass and one train step on CPU, assert output shapes and absence of NaNs.
Also: one decode step against a cache (the serve path), and prefill/decode
consistency for a short prompt.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_arch
from repro.models.model import build
from repro.models.transformer import padded_vocab
from repro.optim.optimizers import sgd


def _batch(cfg, key, b=2, s=32):
    i32 = jnp.int32
    out = {}
    if cfg.enc_layers > 0:
        out["frontend"] = jax.random.normal(key, (b, cfg.enc_seq, cfg.d_model))
        out["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size, i32)
        out["labels"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size, i32)
        return out
    n_text = s
    if cfg.frontend != "none" and cfg.frontend_tokens:
        out["frontend"] = jax.random.normal(key, (b, cfg.frontend_tokens, cfg.d_model))
    out["tokens"] = jax.random.randint(key, (b, n_text), 0, cfg.vocab_size, i32)
    out["labels"] = jax.random.randint(key, (b, n_text), 0, cfg.vocab_size, i32)
    return out


@pytest.fixture(scope="module")
def rng():
    return jax.random.key(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch, rng):
    cfg = get_arch(arch, smoke=True)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    assert cfg.n_experts <= 4
    model = build(cfg)
    params = model.init(rng)
    batch = _batch(cfg, rng)

    # forward
    logits = model.forward(params, batch)
    b, s = batch["tokens"].shape
    exp_s = s + (cfg.frontend_tokens if (cfg.frontend == "vision") else 0)
    assert logits.shape == (b, exp_s, padded_vocab(cfg))
    assert not bool(jnp.isnan(logits).any()), "NaN in forward logits"

    # one SGD train step
    loss, grads = jax.value_and_grad(lambda p: model.loss(p, batch))(params)
    assert np.isfinite(float(loss)), float(loss)
    opt = sgd(1e-2)
    new_params, _ = opt.update(grads, opt.init(params), params)
    loss2 = model.loss(new_params, batch)
    assert np.isfinite(float(loss2))
    # gradients must touch the stack (not just the embedding)
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads))
    )
    assert float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch, rng):
    cfg = get_arch(arch, smoke=True)
    model = build(cfg)
    params = model.init(rng)
    b, cache_len = 2, 64
    caches = model.init_cache(b, cache_len, params=params)
    token = jnp.ones((b, 1), jnp.int32)
    logits, new_caches = model.decode_step(params, token, caches, jnp.asarray(0))
    assert logits.shape == (b, 1, padded_vocab(cfg))
    assert not bool(jnp.isnan(logits).any())
    # cache must actually change
    changed = jax.tree.reduce(
        lambda a, x: a or x,
        jax.tree.map(
            lambda a, b_: bool(jnp.any(a != b_)) if a.dtype != jnp.int32 else False,
            caches, new_caches,
        ),
        False,
    )
    assert changed, "decode step did not write to the cache"


@pytest.mark.parametrize("arch", ["granite_3_8b", "mamba2_130m", "recurrentgemma_9b",
                                  "phi3p5_moe_42b"])
def test_prefill_decode_consistency(arch, rng):
    """Greedy decode over a short prompt must match teacher-forced logits.

    MoE note: capacity-based routing drops tokens that overflow an expert's
    queue, and the competition set differs between teacher-forced prefill
    (whole sequence) and stepwise decode (one token) — so exact consistency
    only holds when capacity is large enough that nothing drops.  We raise
    capacity_factor for this test; the semantic difference at tight capacity
    is inherent to GShard-style MoE, not a bug.
    """
    import dataclasses

    cfg = get_arch(arch, smoke=True)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    model = build(cfg)
    params = model.init(rng)
    b, s = 1, 8
    toks = jax.random.randint(rng, (b, s), 0, cfg.vocab_size, jnp.int32)
    full = model.forward(params, {"tokens": toks})

    caches = model.init_cache(b, 32, params=params)
    outs = []
    for t in range(s):
        lg, caches = model.decode_step(params, toks[:, t:t + 1], caches,
                                       jnp.asarray(t))
        outs.append(lg)
    stepwise = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(full, np.float32), np.asarray(stepwise, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_full_configs_report_sane_param_counts():
    expected = {
        "phi3p5_moe_42b": (35e9, 50e9),
        "llama4_maverick_400b": (330e9, 480e9),
        "recurrentgemma_9b": (7e9, 12e9),
        "mamba2_130m": (0.1e9, 0.2e9),
        "mistral_large_123b": (110e9, 135e9),
        "qwen2_vl_72b": (60e9, 85e9),
        "qwen2p5_32b": (28e9, 40e9),
        "granite_3_8b": (6.5e9, 10e9),
        "phi3_mini_3p8b": (3.2e9, 4.6e9),
        "seamless_m4t_large_v2": (1.2e9, 3.2e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_arch(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"


def test_moe_active_params_below_total():
    cfg = get_arch("phi3p5_moe_42b")
    assert cfg.active_param_count() < 0.3 * cfg.param_count()
