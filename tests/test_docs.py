"""Docs integrity (ISSUE 5): the DESIGN.md the codebase cites must exist,
and every in-code doc citation must resolve (tools/check_doc_links.py —
the same check CI runs, so the four-PR dangling-DESIGN.md situation cannot
recur)."""
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def test_design_md_exists_with_cited_sections():
    text = (ROOT / "docs" / "DESIGN.md").read_text()
    for anchor in ("§4", "§5", "§6", "§7"):
        assert any(ln.startswith("#") and anchor in ln
                   for ln in text.splitlines()), f"DESIGN.md lost {anchor}"
    assert "memory budget" in text.lower()


def test_all_doc_citations_resolve():
    r = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_doc_links.py")],
        capture_output=True, text=True)
    assert r.returncode == 0, f"\n{r.stdout}{r.stderr}"
