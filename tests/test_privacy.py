"""Privacy-subsystem tests (ISSUE 3): the in-scan RDP accountant, the
budget schedulers, and the engine's budget-exhaustion semantics.

* the f32 compensated accountant matches an independently written f64
  offline RDP reference within 1e-6 (relative, floored at 1) on a
  (z × q × steps) grid — the acceptance grid;
* accountant monotonicity: ε shrinks with more noise, grows with larger
  sampling fraction and with more composed steps;
* the trace-safe budget calibration agrees with the host bisection;
* scheduler algebra: runtime codes select the right z_t law, the adaptive
  controller shrinks noise on AUC stalls and respects the floor;
* exhaustion masking freezes the global model BITWISE (the release gate in
  core/rounds.py), the accounted ε never exceeds the lane's budget, and a
  whole budget grid still compiles exactly once;
* the legacy engine rejects scheduled configs instead of ignoring budgets.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig, FLParams, fl_params, fl_static
from repro.data.synthetic import make_federated
from repro.models import mlp as mlp_lib
from repro.privacy import accountant as acct_lib
from repro.privacy import schedule as sched_lib
from repro.train import fl_driver

DELTA = 1e-5


def _offline_epsilon(z: float, q: float, steps: int, delta: float) -> float:
    """Trusted offline reference, re-derived in f64 on purpose (NOT imported
    from repro.privacy): subsampled-Gaussian RDP composed `steps` times,
    converted with the tightened bound over the shared order grid."""
    a = np.asarray(acct_lib.ORDERS, np.float64)
    rdp = steps * np.minimum(a / (2.0 * z * z), 2.0 * q * q * a / (z * z))
    eps = rdp + np.log1p(-1.0 / a) - (np.log(delta) + np.log(a)) / (a - 1.0)
    return float(eps.min())


def _scan_epsilon(z: float, q: float, steps: int, delta: float) -> float:
    """ε from the jit-side accountant after a lax.scan of `steps` rounds —
    exactly how the engine composes it."""
    zf, qf = jnp.float32(z), jnp.float32(q)

    def body(st, _):
        return acct_lib.accountant_step(st, zf, qf), None

    st, _ = jax.lax.scan(body, acct_lib.init_accountant_state(), None,
                         length=steps)
    return float(acct_lib.epsilon_from_state(st, delta))


# ---------------------------------------------------------------------------
# accountant vs offline reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("z", [0.8, 1.2, 2.0, 4.0])
@pytest.mark.parametrize("q", [0.1, 0.25, 1.0])
@pytest.mark.parametrize("steps", [1, 7, 40, 200])
def test_accountant_matches_offline_reference(z, q, steps):
    """Acceptance grid: in-scan f32 ε within 1e-6 of the f64 reference.

    The compensated (Neumaier) RDP carry keeps the composed sum exact to
    ~1 ulp of the total; host-folded f64 conversion constants avoid f32
    transcendentals.  Measured worst case on this grid: ~8e-8."""
    zf = float(np.float32(z))  # feed both sides the same representable z/q
    qf = float(np.float32(q))
    got = _scan_epsilon(zf, qf, steps, DELTA)
    ref = _offline_epsilon(zf, qf, steps, DELTA)
    assert abs(got - ref) <= 1e-6 * max(1.0, abs(ref)), (got, ref)


def test_accountant_matches_host_accountant():
    """The host RdpAccountant (the legacy API, now re-exported from
    core/dp) and the in-scan accountant are the same curve."""
    host = acct_lib.RdpAccountant(DELTA)
    for _ in range(25):
        host.step(1.3, 0.2)
    assert abs(_scan_epsilon(1.3, 0.2, 25, DELTA) - host.epsilon()) < 1e-5
    assert abs(acct_lib.compose_epsilon(1.3, 0.2, 25, DELTA)
               - host.epsilon()) < 1e-12


def test_accountant_monotonicity():
    # more noise -> less privacy loss
    eps_by_z = [_scan_epsilon(z, 0.25, 30, DELTA) for z in (0.6, 1.0, 2.0, 4.0)]
    assert all(a > b for a, b in zip(eps_by_z, eps_by_z[1:])), eps_by_z
    # larger cohort fraction -> more privacy loss (strict while the
    # amplification term binds, i.e. 2q² < 1/2; beyond q=0.5 it saturates
    # at the unamplified Gaussian — check that plateau too)
    eps_by_q = [_scan_epsilon(1.2, q, 30, DELTA) for q in (0.05, 0.1, 0.2, 0.4)]
    assert all(a < b for a, b in zip(eps_by_q, eps_by_q[1:])), eps_by_q
    assert _scan_epsilon(1.2, 0.8, 30, DELTA) == _scan_epsilon(1.2, 1.0, 30, DELTA)
    # composition only loses privacy
    eps_by_s = [_scan_epsilon(1.2, 0.25, s, DELTA) for s in (1, 5, 25, 125)]
    assert all(a < b for a, b in zip(eps_by_s, eps_by_s[1:])), eps_by_s
    # empty accountant reports zero
    st = acct_lib.init_accountant_state()
    assert float(acct_lib.epsilon_from_state(st, DELTA)) == 0.0


def test_budget_calibration_rt_matches_host():
    """The jit bisection and the host bisection land on the same z, and the
    calibrated z meets its budget under composition."""
    for eps_total, rounds, q in ((8.0, 40, 0.25), (100.0, 60, 0.2),
                                 (2000.0, 50, 0.5)):
        z_host = acct_lib.noise_multiplier_for_budget(eps_total, DELTA,
                                                      rounds, q)
        z_rt = float(jax.jit(
            lambda e: acct_lib.noise_multiplier_for_budget_rt(
                e, DELTA, rounds, q))(jnp.float32(eps_total)))
        assert abs(z_rt - z_host) / z_host < 1e-3, (z_rt, z_host)
        assert acct_lib.compose_epsilon(z_rt, q, rounds, DELTA) <= eps_total * (1 + 1e-4)


# ---------------------------------------------------------------------------
# schedulers
# ---------------------------------------------------------------------------


def _pr(**kw) -> FLParams:
    return fl_params(FLConfig()).\
        _replace(**{k: jnp.float32(v) for k, v in kw.items()})


def test_schedule_codes_select_the_law():
    st = sched_lib.SchedulerState(z_base=jnp.float32(2.0),
                                  boost=jnp.float32(0.5),
                                  best_auc=jnp.float32(0.0))
    rounds = 11
    mid = jnp.asarray(5, jnp.int32)  # t = 0.5 exactly -> linear == base
    z_uni = float(sched_lib.scheduled_multiplier(
        st, _pr(dp_sched=0.0), mid, rounds))
    z_lin0 = float(sched_lib.scheduled_multiplier(
        st, _pr(dp_sched=1.0, dp_sched_rate=0.4), jnp.asarray(0, jnp.int32),
        rounds))
    z_lin_end = float(sched_lib.scheduled_multiplier(
        st, _pr(dp_sched=1.0, dp_sched_rate=0.4),
        jnp.asarray(rounds - 1, jnp.int32), rounds))
    z_ada = float(sched_lib.scheduled_multiplier(
        st, _pr(dp_sched=2.0), mid, rounds))
    assert z_uni == 2.0
    np.testing.assert_allclose(z_lin0, 2.0 * 1.4, rtol=1e-6)
    np.testing.assert_allclose(z_lin_end, 2.0 * 0.6, rtol=1e-6)
    np.testing.assert_allclose(z_ada, 2.0 * 0.5, rtol=1e-6)


def test_adaptive_controller_spends_on_stall():
    pr = _pr(dp_sched_rate=0.5, dp_stall_tol=1e-3)
    st = sched_lib.init_scheduler(jnp.float32(50.0), DELTA, 40,
                                  jnp.float32(0.25))
    assert float(st.boost) == 1.0
    # improving AUC: boost untouched
    st = sched_lib.scheduler_update(st, jnp.float32(0.7), pr)
    assert float(st.boost) == 1.0 and float(st.best_auc) == pytest.approx(0.7)
    # stalled AUC: noise shrinks by (1 - rate)
    st = sched_lib.scheduler_update(st, jnp.float32(0.7), pr)
    assert float(st.boost) == pytest.approx(0.5)
    # repeated stalls bottom out at the floor
    for _ in range(10):
        st = sched_lib.scheduler_update(st, jnp.float32(0.7), pr)
    assert float(st.boost) == pytest.approx(sched_lib.BOOST_FLOOR)
    # fresh improvement stops the decay without raising it back
    st2 = sched_lib.scheduler_update(st, jnp.float32(0.9), pr)
    assert float(st2.boost) == float(st.boost)
    assert float(st2.best_auc) == pytest.approx(0.9)


# ---------------------------------------------------------------------------
# engine integration: exhaustion masking + budget sweeps
# ---------------------------------------------------------------------------

ROUNDS = 12
EVAL_EVERY = 4


@pytest.fixture(scope="module")
def fed():
    return make_federated(0, "unsw", n_samples=800, n_clients=6)


@pytest.fixture(scope="module")
def fl():
    return FLConfig(n_clients=6, clients_per_round=3, rounds=ROUNDS,
                    local_epochs=2, local_batch=16, local_lr=0.08,
                    dp_enabled=True, dp_mode="clipped", dp_clip=1.0,
                    dp_scheduled=True, fault_tolerance=True,
                    failure_prob=0.05)


def _single_run_params(fl, fed, budget, rounds=ROUNDS):
    """Final params of the compiled single-lane engine at a given budget."""
    from repro.models.spec import meta_for

    static = fl_static(fl)
    run = jax.jit(fl_driver._build_single_run(static, rounds, EVAL_EVERY,
                                              meta_for(fed, hidden=16)))
    stack, ds, dq = fl_driver._device_federation(fed)
    pr = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32),
                      fl_params(fl)._replace(dp_budget=budget))
    params, _, trace = run(jax.random.key(0), stack, ds, dq, pr)
    return params, trace


def test_exhaustion_freezes_global_model_bitwise(fed, fl):
    """A budget below the conversion floor makes every release overshoot:
    the masked aggregation must keep the global model BITWISE at init — the
    gate selects old params, it does not add a zero (which could still
    flip low bits through the server optimizer)."""
    # 0.01 < min_alpha conversion const (~0.019 at delta=1e-5): no z can fit
    params, trace = _single_run_params(fl, fed, 0.01)
    init = jax.jit(mlp_lib.init_mlp, static_argnums=(1, 2, 3))(
        jax.random.fold_in(jax.random.key(0), 0), fed.n_features, 16,
        fed.n_classes)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(init)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert np.all(np.asarray(trace["live"]) == 0.0)
    assert np.all(np.asarray(trace["eps"]) == 0.0)  # nothing was released
    # a longer frozen run ends at the same bits (freeze, not slow drift)
    params20, _ = _single_run_params(fl, fed, 0.01, rounds=20)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params20)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_live_budget_moves_the_model_and_respects_budget(fed, fl):
    params, trace = _single_run_params(fl, fed, 300.0)
    init = jax.jit(mlp_lib.init_mlp, static_argnums=(1, 2, 3))(
        jax.random.fold_in(jax.random.key(0), 0), fed.n_features, 16,
        fed.n_classes)
    assert any(bool(jnp.any(a != b)) for a, b in
               zip(jax.tree.leaves(params), jax.tree.leaves(init)))
    eps = np.asarray(trace["eps"])
    assert np.all(np.diff(eps) >= -1e-6)           # spend is monotone
    assert np.all(eps <= 300.0 * (1 + 1e-5))       # and never overshoots


def test_budget_grid_single_compile_and_ordered_frontier(fed, fl):
    """A (budget × schedule) grid is one compiled program; more budget ->
    less noise; each lane's accounted ε stays within its own budget."""
    budgets = (50.0, 200.0, 800.0, 3200.0)
    cells = [{"dp_budget": b} for b in budgets]
    cells.append({"dp_budget": 800.0, "dp_sched": sched_lib.schedule_code("adaptive"),
                  "dp_stall_tol": 10.0})  # impossible tol -> always stalls
    m0 = fl_driver.RUNNER_STATS["misses"]
    sweep = fl_driver.run_fl_sweep(fed, fl, cells, seeds=(0, 1),
                                   rounds=ROUNDS, eval_every=EVAL_EVERY)
    assert fl_driver.RUNNER_STATS["misses"] - m0 <= 1
    sigmas = [row[0].history["sigma"][0] for row in sweep[:4]]
    assert all(a > b for a, b in zip(sigmas, sigmas[1:])), sigmas
    for (cell, row) in zip(cells, sweep):
        for r in row:
            assert r.eps_spent <= cell["dp_budget"] * (1 + 1e-5)
            assert r.history["eps"][-1] == r.eps_spent
    # the always-stalling adaptive lane spends faster than uniform at the
    # same budget: noise decays across eval blocks
    ada = sweep[4][0].history["sigma"]
    uni = sweep[2][0].history["sigma"]
    assert ada[-1] < ada[0] and uni[-1] == pytest.approx(uni[0])
    assert sweep[4][0].history["eps"][-1] >= sweep[2][0].history["eps"][-1]


def test_unscheduled_configs_and_legacy_are_unchanged(fed, fl):
    """dp_scheduled=False must keep the PR 2 behaviour: host closed-form ε,
    no eps/sigma history columns; the legacy loop refuses scheduled
    configs loudly."""
    plain = dataclasses.replace(fl, dp_scheduled=False, dp_epsilon=200.0)
    r = fl_driver.run_fl(fed, plain, seed=0, rounds=6, eval_every=3)
    assert "eps" not in r.history and "sigma" not in r.history
    assert r.eps_spent == pytest.approx(
        acct_lib.accounted_epsilon(dataclasses.replace(
            plain, selection="adaptive_utility"), 6))
    with pytest.raises(ValueError, match="dp_scheduled"):
        fl_driver.run_fl_legacy(fed, fl, seed=0, rounds=4)
    with pytest.raises(ValueError, match="in-scan accountant"):
        acct_lib.accounted_epsilon(fl, 4)


def test_scheduled_requires_clipped_mode(fed, fl):
    """dp_scheduled + dp_mode='paper' would certify an (ε, δ) guarantee
    for an UNCLIPPED mechanism (unbounded sensitivity) — the engine must
    refuse rather than report a mathematically false ε."""
    bad = dataclasses.replace(fl, dp_mode="paper")
    with pytest.raises(ValueError, match="clipped"):
        fl_driver.run_fl(fed, bad, seed=0, rounds=4, eval_every=2)


def test_spent_epsilon_deprecated_alias(fed, fl):
    plain = dataclasses.replace(fl, dp_scheduled=False)
    with pytest.warns(DeprecationWarning):
        eps = fl_driver.spent_epsilon(plain, 10)
    assert eps == pytest.approx(acct_lib.accounted_epsilon(plain, 10))
