"""Streaming anomaly-scoring engine tests (ISSUE 7).

The serving contract is BITWISE: whatever bucket the batcher picks and
whatever padding it adds, the scores the engine emits must equal the
same-route ``ModelSpec.predict_proba`` on the same windows, bit for bit —
batching and double-buffered feeding are pure perf machinery, not math.
Covered here:

* batching properties (plan/pad/Bucketer order + zero-copy emission);
* engine-vs-reference bitwise on tabular (mlp) and windowed (cnn) specs,
  and on BOTH kernel routes of the ``attn`` sequence detector;
* scorer-cache statics keying: one compile per (model, bucket), zero on
  rerun — the serving twin of the training engine's runner-cache test;
* checkpoint round-trip of real trained engine artifacts for EVERY
  registered spec (satellite: checkpoint/checkpoint.py coverage);
* personalized per-client heads: serving client i ≡ fine-tuning client i;
* the double-buffered feed preserves order and content;
* flash-decode interpret auto-routing (CPU → interpret mode);
* ``prefill_scan`` ≡ the one-token-at-a-time prefill loop, bitwise.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.data.synthetic import make_federated
from repro.kernels.flash_decode import resolve_interpret
from repro.models.spec import get_model_spec, meta_for, model_names
from repro.serve import (SERVE_STATS, Bucketer, ServeEngine, batches_of,
                         bucket_for, device_feed, pad_to, plan_chunks,
                         save_serving_checkpoint)
from repro.train.fl_driver import (personalized_client_params, run_fl)


@pytest.fixture(scope="module")
def fed_tab():
    return make_federated(0, "unsw", n_samples=1200, n_clients=6)


@pytest.fixture(scope="module")
def fed_road():
    return make_federated(0, "road_raw", n_samples=700, n_clients=6)


def _fl(model: str, n_clients: int = 6) -> FLConfig:
    return FLConfig(n_clients=n_clients, clients_per_round=3, rounds=4,
                    local_epochs=2, local_batch=16, local_lr=0.08,
                    dp_enabled=False, fault_tolerance=False, model=model)


def _train(fed, model: str):
    res = run_fl(fed, _fl(model), "random", seed=0, rounds=4, eval_every=2,
                 return_params=True)
    assert res.params is not None
    return res.params


def _ref_scores(spec, params, x, route) -> np.ndarray:
    """The pinned reference: COMPILED ``predict_proba_routed`` on the exact
    windows, no padding, no bucketing.  Compiled (not eager) because XLA's
    op-by-op eager dispatch fuses differently from jit and can differ in
    the last ULP on reduction-heavy routes; the serving contract is that
    batching, padding and feeding change no bits relative to the compiled
    single-shot reference."""
    fn = jax.jit(lambda p, z: spec.predict_proba_routed(p, z, route))
    return np.asarray(fn(params, jnp.asarray(x))[:, 1])


@pytest.fixture(scope="module")
def mlp_trained(fed_tab):
    return _train(fed_tab, "mlp")


# ---------------------------------------------------------------------------
# batching
# ---------------------------------------------------------------------------


def test_plan_chunks_covers_and_uses_buckets():
    buckets = (8, 32)
    for n in (1, 7, 8, 9, 31, 32, 33, 100, 129):
        chunks = plan_chunks(n, buckets)
        assert sum(chunks) >= n
        assert all(c in buckets for c in chunks)
        # greedy: everything except the remainder runs at the max bucket
        assert all(c == 32 for c in chunks[:-1])


def test_bucket_for_picks_smallest_fit():
    assert bucket_for(1, (8, 32)) == 8
    assert bucket_for(8, (8, 32)) == 8
    assert bucket_for(9, (8, 32)) == 32
    with pytest.raises(ValueError, match="exceed"):
        bucket_for(33, (8, 32))


def test_pad_to_preserves_rows():
    x = np.arange(12, dtype=np.float32).reshape(3, 4)
    padded, n = pad_to(x, 8)
    assert n == 3 and padded.shape == (8, 4)
    assert np.array_equal(padded[:3], x) and not padded[3:].any()


def test_bucketer_preserves_order_and_emits_zero_copy():
    rng = np.random.default_rng(0)
    chunks = [rng.normal(size=(m, 5)).astype(np.float32)
              for m in (3, 40, 1, 31, 7)]
    bk = Bucketer((8, 32))
    batches = []
    for c in chunks:
        batches.extend(bk.add(c))
    batches.extend(bk.flush())
    assert bk.pending == 0
    # full batches are exactly max-bucket sized, remainder batches padded
    assert all(b.shape[0] in (8, 32) for b, _ in batches)
    got = np.concatenate([b[:n] for b, n in batches])
    assert np.array_equal(got, np.concatenate(chunks))


def test_batches_of_roundtrip():
    rng = np.random.default_rng(1)
    chunks = [rng.normal(size=(m, 3)).astype(np.float32) for m in (5, 9, 2)]
    got = np.concatenate(
        [b[:n] for b, n in batches_of(iter(chunks), (4, 16))])
    assert np.array_equal(got, np.concatenate(chunks))


# ---------------------------------------------------------------------------
# feed
# ---------------------------------------------------------------------------


def test_device_feed_preserves_order_and_content():
    rng = np.random.default_rng(2)
    batches = [(rng.normal(size=(4, 3)).astype(np.float32), 4 - i)
               for i in range(5)]
    out = list(device_feed(iter(batches)))
    assert [n for _, n in out] == [n for _, n in batches]
    for (xd, _), (xh, _) in zip(out, batches):
        assert isinstance(xd, jax.Array)
        assert np.array_equal(np.asarray(xd), xh)
    assert list(device_feed(iter([]))) == []


# ---------------------------------------------------------------------------
# engine vs reference: bitwise
# ---------------------------------------------------------------------------


def test_engine_bitwise_mlp_uneven(fed_tab, mlp_trained):
    meta = meta_for(fed_tab)
    spec = get_model_spec("mlp", meta)
    eng = ServeEngine(spec, meta, mlp_trained, buckets=(8, 32))
    x = np.asarray(fed_tab.test_x[:37], np.float32)   # 32 + padded 8
    ref = _ref_scores(spec, mlp_trained, x, eng.route)
    assert np.array_equal(eng.score(x), ref)
    # streamed in awkward arrival chunks: same bits, same order
    rep = eng.score_stream([x[i:i + 11] for i in range(0, 37, 11)])
    assert np.array_equal(rep.scores, ref)
    assert rep.n_windows == 37 and rep.n_batches == len(rep.batch_walls_s)
    assert rep.windows_per_sec > 0 and rep.p99_s >= rep.p50_s


def test_engine_bitwise_cnn_windowed(fed_road):
    params = _train(fed_road, "cnn")
    meta = meta_for(fed_road)
    spec = get_model_spec("cnn", meta)
    eng = ServeEngine(spec, meta, params, buckets=(8, 32))
    x = np.asarray(fed_road.test_x[:21], np.float32)
    ref = _ref_scores(spec, params, x, eng.route)
    assert np.array_equal(eng.score(x), ref)


def test_engine_bitwise_attn_both_routes(fed_road):
    """The sequence detector must serve bitwise on BOTH kernel routes:
    'kernel' (Pallas flash_attention/flash_decode — interpret mode on CPU)
    and 'ref' (the pure-jnp oracles)."""
    params = _train(fed_road, "attn")
    meta = meta_for(fed_road)
    spec = get_model_spec("attn", meta)
    x = np.asarray(fed_road.test_x[:13], np.float32)
    for route in ("kernel", "ref"):
        eng = ServeEngine(spec, meta, params, buckets=(4, 16), route=route)
        ref = _ref_scores(spec, params, x, route)
        assert np.array_equal(eng.score(x), ref), route


def test_engine_bitwise_ssm_stream_both_routes(fed_road):
    """ISSUE 10: the Mamba-2 detector's full streaming path —
    ``score_stream`` over uneven chunks through bucket batching and the
    double-buffered feed — is bitwise against the compiled single-shot
    reference on BOTH kernel routes ('kernel' = the rglru_scan Pallas
    inter-chunk recurrence, 'ref' = the kernels/ref oracle)."""
    params = _train(fed_road, "ssm")
    meta = meta_for(fed_road)
    spec = get_model_spec("ssm", meta)
    x = np.asarray(fed_road.test_x[:21], np.float32)
    for route in ("kernel", "ref"):
        eng = ServeEngine(spec, meta, params, buckets=(4, 16), route=route)
        ref = _ref_scores(spec, params, x, route)
        rep = eng.score_stream([x[i:i + 8] for i in range(0, 21, 8)])
        assert np.array_equal(rep.scores, ref), route
        assert rep.n_windows == 21
    # and the two routes agree with each other bit-for-bit: the inter-chunk
    # scan is the same sequential f32 recurrence in both implementations
    k = _ref_scores(spec, params, x, "kernel")
    r = _ref_scores(spec, params, x, "ref")
    assert np.array_equal(k, r)


def test_engine_rejects_unknown_route(fed_road):
    meta = meta_for(fed_road)
    spec = get_model_spec("attn", meta)
    with pytest.raises(KeyError, match="no score route"):
        ServeEngine(spec, meta, spec.init(jax.random.key(0)), route="nope")


# ---------------------------------------------------------------------------
# scorer cache: one compile per (model, bucket)
# ---------------------------------------------------------------------------


def test_scorer_cache_single_compile(fed_tab, mlp_trained):
    meta = meta_for(fed_tab)
    spec = get_model_spec("mlp", meta)
    eng = ServeEngine(spec, meta, mlp_trained, buckets=(8, 32))
    x = np.asarray(fed_tab.test_x[:70], np.float32)

    eng.warmup()
    before = dict(SERVE_STATS)
    eng.score(x)
    eng.score_stream([x[i:i + 17] for i in range(0, 70, 17)])
    after = dict(SERVE_STATS)
    assert after["misses"] == before["misses"], \
        "serving after warmup must not compile new programs"
    assert after["hits"] > before["hits"]

    # a second engine on the same (model, meta, buckets): all cache hits
    before = dict(SERVE_STATS)
    eng2 = ServeEngine(spec, meta, mlp_trained, buckets=(8, 32))
    eng2.score(x)
    assert SERVE_STATS["misses"] == before["misses"]


# ---------------------------------------------------------------------------
# checkpoint round-trip: every registered spec (satellite 3)
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_all_registered_specs(tmp_path, fed_tab,
                                                   fed_road, mlp_trained):
    """Train → save → restore → score: bitwise, for every registered model.

    This is the checkpoint substrate exercised with REAL engine artifacts
    (run_fl final params), not toy pytrees: '/'-joined key flattening,
    dtype round-trip through .npz, manifest-driven template rebuild."""
    for name in sorted(model_names()):
        fed = fed_tab if name == "mlp" else fed_road
        params = mlp_trained if name == "mlp" else _train(fed, name)
        meta = meta_for(fed)
        spec = get_model_spec(name, meta)
        path = save_serving_checkpoint(str(tmp_path / f"serve_{name}"),
                                       params, name, meta)
        eng = ServeEngine.from_checkpoint(path)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(eng.params)):
            assert np.array_equal(np.asarray(a), np.asarray(b)), name
        x = np.asarray(fed.test_x[:9], np.float32)
        want = _ref_scores(spec, params, x, eng.route)
        assert np.array_equal(eng.score(x), want), name


def test_from_checkpoint_rejects_non_serving(tmp_path):
    from repro.checkpoint import checkpoint as ckpt_lib
    p = ckpt_lib.save_pytree(str(tmp_path / "plain"), {"w": np.ones(3)})
    with pytest.raises(ValueError, match="not a serving checkpoint"):
        ServeEngine.from_checkpoint(p)


# ---------------------------------------------------------------------------
# personalized per-client heads
# ---------------------------------------------------------------------------


def test_personalized_heads_bitwise(tmp_path, fed_tab, mlp_trained):
    from repro.train.fl_driver import export_personalized
    meta = meta_for(fed_tab)
    spec = get_model_spec("mlp", meta)
    heads = export_personalized(mlp_trained, fed_tab, spec)
    path = save_serving_checkpoint(str(tmp_path / "serve_p"), mlp_trained,
                                   "mlp", meta, heads=heads)
    eng = ServeEngine.from_checkpoint(path, buckets=(8, 32))
    assert eng.n_personalized == fed_tab.n_clients

    per_client = personalized_client_params(mlp_trained, fed_tab, spec)
    x = np.asarray(fed_tab.test_x[:11], np.float32)
    for ci in (0, fed_tab.n_clients - 1):
        want = _ref_scores(spec, per_client[ci], x, eng.route)
        assert np.array_equal(eng.score(x, client=ci), want)

    with pytest.raises(ValueError, match="no personalized heads"):
        ServeEngine(spec, meta, mlp_trained).score(x, client=0)


# ---------------------------------------------------------------------------
# kernels: interpret auto-routing (satellite 1)
# ---------------------------------------------------------------------------


def test_resolve_interpret_routes_by_backend():
    # explicit values pass through untouched
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    # None resolves by backend: interpret everywhere except real TPU
    expect = jax.default_backend() != "tpu"
    assert resolve_interpret(None) is expect


# ---------------------------------------------------------------------------
# prefill scan (satellite 2)
# ---------------------------------------------------------------------------


def test_prefill_scan_matches_loop():
    from repro.configs.base import get_arch
    from repro.launch.serve import prefill_scan
    from repro.models.model import build

    cfg = get_arch("mamba2_130m", smoke=True)
    model = build(cfg)
    params = model.init(jax.random.key(0))
    w = cfg.sliding_window
    prompts = jax.random.randint(jax.random.key(1), (2, 10), 0,
                                 cfg.vocab_size, jnp.int32)
    decode = jax.jit(
        lambda p, t, c, i: model.decode_step(p, t, c, i, window=w))

    caches = model.init_cache(2, 16, params=params, window=w)
    logits = None
    for t in range(10):
        logits, caches = decode(params, prompts[:, t:t + 1], caches,
                                jnp.asarray(t))

    caches_s = model.init_cache(2, 16, params=params, window=w)
    logits_s, caches_s = prefill_scan(model, params, prompts, caches_s,
                                      window=w)
    assert np.array_equal(np.asarray(logits), np.asarray(logits_s))
    for a, b in zip(jax.tree.leaves(caches), jax.tree.leaves(caches_s)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
