"""Execution-plan registry (core/plans.py) — the RoundPlan contract.

Four layers of pins:

* **Golden bitwise pins** — the three pre-refactor plans replayed against
  ``tests/golden/plans_prerefactor.json`` (captured by
  ``tests/capture_golden_plans.py`` BEFORE the registry landed): the
  refactor may not move a single bit of any default lane.
* **Registry contract** — names, families, lane codes, builder resolution
  and the code→plan inverse.
* **Rejection paths** — unknown plans and plan/feature combinations the
  registry marks incompatible fail loudly at config build or front-door
  time (pre-registry, ``run_fl(plan="client_serial")`` SILENTLY ran the
  parallel program).
* **New-plan semantics** — a mixed sync × async × hierarchical sweep
  compiles as ONE program; zero-staleness buffered_async is bitwise
  synchronous FedAvg on every model-path column; the async K-th-arrival
  time model undercuts the synchronous slowest-client wall under
  stragglers.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig, fl_params, fl_static
from repro.core import plans as plans_lib
from repro.core import rounds as rounds_lib
from repro.data.synthetic import (make_federated, make_population,
                                  round_batches)
from repro.models.spec import get_model_spec, meta_for
from repro.train import fl_driver

GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)), "golden",
                      "plans_prerefactor.json")


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def fed8():
    return make_federated(0, "unsw", n_samples=600, n_clients=8)


# ---------------------------------------------------------------------------
# Registry contract
# ---------------------------------------------------------------------------


def test_registry_contract():
    assert plans_lib.plan_names() == (
        "client_parallel", "client_serial", "client_cohort",
        "buffered_async", "hierarchical")
    # same-family plans share the compiled program; codes pick the lane
    for name, family, code in [("client_parallel", "client_parallel", 0.0),
                               ("buffered_async", "client_parallel", 1.0),
                               ("hierarchical", "client_parallel", 2.0),
                               ("client_serial", "client_serial", 0.0),
                               ("client_cohort", "client_cohort", 0.0)]:
        p = plans_lib.get_plan(name)
        assert (p.family, p.code) == (family, code)
        assert plans_lib.plan_for_code(family, code).name == name
        assert callable(p.builder_fn())
    # capability flags gate the front doors
    assert not plans_lib.get_plan("client_serial").driver_capable
    assert not plans_lib.get_plan("client_cohort").driver_capable
    assert plans_lib.get_plan("client_cohort").cohort_capable
    assert not plans_lib.get_plan("buffered_async").cohort_capable
    assert plans_lib.get_plan("buffered_async").fault_arrivals


def test_static_runtime_split_of_plans():
    fl = FLConfig(plan="buffered_async", async_buffer=4.0)
    # runtime: the concrete plan is the plan_code lane
    assert fl_params(fl).plan_code == 1.0
    assert fl_params(FLConfig()).plan_code == 0.0
    # static: the name canonicalises to the program family, async knobs
    # reset to defaults — sync and async configs share one cache entry
    assert fl_static(fl) == fl_static(FLConfig())
    assert fl_static(FLConfig(plan="hierarchical")) == fl_static(FLConfig())


def test_plan_transient_buffers_routes_through_registry():
    from repro.core import scale as scale_lib
    assert scale_lib.plan_transient_buffers("buffered_async") == 2
    assert scale_lib.plan_transient_buffers("client_parallel") == 0
    assert scale_lib.plan_transient_buffers("client_cohort") == 0


def test_sharding_rules_key_on_family():
    from repro.models.sharding import make_rules
    assert make_rules("buffered_async", False) == make_rules(
        "client_parallel", False)
    assert make_rules("hierarchical", True) == make_rules(
        "client_parallel", True)
    assert make_rules("client_serial", False) != make_rules(
        "client_parallel", False)


# ---------------------------------------------------------------------------
# Rejection paths
# ---------------------------------------------------------------------------


def test_unknown_plan_rejected_at_config_build():
    with pytest.raises(ValueError, match="unknown FLConfig.plan"):
        FLConfig(plan="fedsgd")


def test_async_knob_inconsistencies_rejected():
    with pytest.raises(ValueError, match="async_buffer"):
        FLConfig(plan="buffered_async")          # needs async_buffer >= 1
    with pytest.raises(ValueError, match="async_buffer"):
        FLConfig(plan="client_parallel", async_buffer=3.0)
    with pytest.raises(ValueError, match="hierarchy_edges"):
        FLConfig(plan="hierarchical", hierarchy_edges=0)


def test_driver_rejects_non_driver_capable_plans(fed8):
    fl = FLConfig(n_clients=8, plan="client_serial")
    with pytest.raises(ValueError, match="client_serial"):
        fl_driver.run_fl(fed8, fl, rounds=2, eval_every=1)


def test_population_rejects_non_cohort_capable_plans():
    pop = make_population(0, n_clients=32, pool_samples=400,
                          members_per_client=8)
    for plan, extra in [("buffered_async", {"async_buffer": 4.0}),
                        ("hierarchical", {}), ("client_serial", {})]:
        fl = FLConfig(n_clients=32, clients_per_round=4, k_max=4,
                      plan=plan, **extra)
        with pytest.raises(ValueError, match="cohort_capable"):
            fl_driver.run_fl_population(pop, fl, seeds=(0,), rounds=2,
                                        eval_every=1)


def test_population_requires_k_max():
    with pytest.raises(ValueError, match="k_max"):
        plans_lib.validate_plan(FLConfig(plan="client_cohort", k_max=0))


def test_sweep_rejects_cross_family_cells(fed8):
    fl = FLConfig(n_clients=8, rounds=2)
    with pytest.raises(ValueError):
        fl_driver.run_fl_sweep(fed8, fl, [{"plan": "client_serial"}],
                               seeds=(0,), rounds=2, eval_every=1)


def test_legacy_driver_rejects_async():
    fed = make_federated(0, "unsw", n_samples=200, n_clients=4)
    fl = FLConfig(n_clients=4, plan="buffered_async", async_buffer=2.0)
    with pytest.raises(ValueError, match="run_fl_legacy"):
        fl_driver.run_fl_legacy(fed, fl, rounds=2, eval_every=1)


# ---------------------------------------------------------------------------
# Golden bitwise pins (pre-refactor capture)
# ---------------------------------------------------------------------------


def test_parallel_plan_bitwise_pinned(golden, fed8):
    fl = FLConfig(n_clients=8, clients_per_round=3, rounds=6, local_epochs=2,
                  local_batch=16, local_lr=0.08, dp_enabled=True,
                  dp_mode="clipped", dp_epsilon=200.0, dp_clip=5.0,
                  fault_tolerance=True, failure_prob=0.1)
    r = fl_driver.run_fl(fed8, fl, "proposed", seed=3, rounds=6, eval_every=2)
    assert r.history == golden["parallel"]["history"]
    assert r.sim_time_s == golden["parallel"]["sim_time_s"]


def test_serial_plan_bitwise_pinned(golden):
    fed = make_federated(1, "unsw", n_samples=400, n_clients=6)
    fl = FLConfig(n_clients=6, clients_per_round=3, rounds=4, local_epochs=2,
                  local_batch=8, local_lr=0.05, dp_enabled=True,
                  dp_mode="clipped", dp_epsilon=100.0, dp_clip=2.0,
                  plan="client_serial", serial_clients_in_step=3,
                  fault_tolerance=True, failure_prob=0.1)
    meta = meta_for(fed, hidden=16)
    spec = get_model_spec(fl.model, meta)
    key = jax.random.key(7)
    params = spec.init(jax.random.fold_in(key, 0))
    sizes = fed.data_sizes()
    state = rounds_lib.init_round_state(
        params, fl, jax.random.fold_in(key, 1), n_clients=fed.n_clients,
        data_size=jnp.asarray(sizes / sizes.mean()),
        data_quality=jnp.asarray(fed.label_entropy()))
    # builder resolved through the registry, as launch/steps.py now does
    builder = plans_lib.get_plan(fl.plan).builder_fn()
    assert builder is rounds_lib.make_serial_round
    step = jax.jit(builder(spec.loss, fl, fed.n_clients))
    rng = np.random.default_rng(5)
    g = golden["serial"]
    for i in range(2):
        batches = jax.tree.map(jnp.asarray, round_batches(
            rng, fed, fl.local_epochs, fl.local_batch))
        batches = jax.tree.map(lambda x: x[: fl.serial_clients_in_step],
                               batches)
        state, m = step(state, batches)
        assert float(m.global_loss) == g["global_loss"][i]
        assert float(m.k_effective) == g["k_effective"][i]
        np.testing.assert_array_equal(np.asarray(m.sel_mask),
                                      np.asarray(g["sel_mask"][i]))
        np.testing.assert_array_equal(np.asarray(m.update_norms),
                                      np.asarray(g["norms"][i]))


def test_cohort_plan_bitwise_pinned(golden):
    pop = make_population(0, n_clients=64, pool_samples=600,
                          members_per_client=16)
    fl = FLConfig(n_clients=64, clients_per_round=8, k_max=8, rounds=6,
                  local_epochs=2, local_batch=16, local_lr=0.08,
                  fault_tolerance=True, failure_prob=0.05)
    r = fl_driver.run_fl_population(pop, fl, seeds=(0,), rounds=6,
                                    eval_every=3)[0][0]
    assert r.history == golden["cohort"]["history"]
    assert r.sim_time_s == golden["cohort"]["sim_time_s"]


def test_fault_sweep_bitwise_pinned(golden, fed8):
    fl = FLConfig(n_clients=8, clients_per_round=3, rounds=4, local_epochs=2,
                  local_batch=16, local_lr=0.08, dp_enabled=True,
                  dp_mode="clipped", dp_epsilon=200.0, dp_clip=5.0,
                  fault_tolerance=True, failure_prob=0.05)
    cells = [{"fault_process": 0.0, "failure_prob": 0.3},
             {"fault_process": 1.0, "failure_prob": 0.3},
             {"fault_process": 3.0, "failure_prob": 0.3}]
    sweep = fl_driver.run_fl_sweep(fed8, fl, cells, seeds=(0, 1), rounds=4,
                                   eval_every=2)
    for ci, row in enumerate(sweep):
        for si, r in enumerate(row):
            assert r.history == golden["sweep"]["histories"][ci][si]


# ---------------------------------------------------------------------------
# New-plan semantics
# ---------------------------------------------------------------------------


def test_mixed_plan_frontier_single_compile(fed8):
    """(sync, buffered_async, hierarchical) — one compiled program, and the
    async lane's simulated wall undercuts sync under stragglers."""
    fl = FLConfig(n_clients=8, clients_per_round=4, rounds=6, local_epochs=2,
                  local_batch=16, local_lr=0.08, failure_prob=0.2,
                  fault_process=3.0, straggler_slow=8.0)
    fl_driver._RUNNER_CACHE.clear()
    m0 = fl_driver.RUNNER_STATS["misses"]
    cells = [{}, {"plan": "buffered_async", "async_buffer": 2.0},
             {"plan": "hierarchical"}]
    res = fl_driver.run_fl_sweep(fed8, fl, cells, seeds=(0, 1), rounds=6,
                                 eval_every=2)
    assert fl_driver.RUNNER_STATS["misses"] - m0 == 1
    sync_t = [r.sim_time_s for r in res[0]]
    async_t = [r.sim_time_s for r in res[1]]
    # K-th arrival (K=2 of 4) never waits for the 8x straggler tail
    assert all(a < s for a, s in zip(async_t, sync_t))
    for row in res:
        for r in row:
            assert np.isfinite(r.auc) and 0.0 <= r.auc <= 1.0


def test_zero_staleness_async_is_sync_fedavg_bitwise(fed8):
    """async_staleness_pow=0 -> (1+s)^-0.0 == 1.0 exactly (IEEE pow), and
    with K = cohort the buffer flushes full: every model-path history
    column must be bitwise the synchronous FedAvg lane.  Only the time
    model (cum_time) may differ — that is the plan's point."""
    fl = FLConfig(n_clients=8, clients_per_round=8, rounds=6, local_epochs=2,
                  local_batch=16, local_lr=0.08, failure_prob=0.1)
    r_sync = fl_driver.run_fl_sweep(fed8, fl, [{}], seeds=(0,), rounds=6,
                                    eval_every=2)[0][0]
    r_async = fl_driver.run_fl_sweep(
        fed8, fl, [{"plan": "buffered_async", "async_buffer": 8.0,
                    "async_staleness_pow": 0.0}],
        seeds=(0,), rounds=6, eval_every=2)[0][0]
    for col in ("acc", "auc", "loss", "k", "fail"):
        assert r_sync.history[col] == r_async.history[col], col
    assert r_sync.history["cum_time"] != r_async.history["cum_time"]


def test_staleness_discount_changes_aggregation(fed8):
    """A positive staleness power down-weights late arrivals — the async
    lane's trained model must actually diverge from sync FedAvg."""
    fl = FLConfig(n_clients=8, clients_per_round=8, rounds=6, local_epochs=2,
                  local_batch=16, local_lr=0.08, failure_prob=0.1)
    r_sync = fl_driver.run_fl_sweep(fed8, fl, [{}], seeds=(0,), rounds=6,
                                    eval_every=2)[0][0]
    r_async = fl_driver.run_fl_sweep(
        fed8, fl, [{"plan": "buffered_async", "async_buffer": 2.0,
                    "async_staleness_pow": 1.0}],
        seeds=(0,), rounds=6, eval_every=2)[0][0]
    assert r_sync.history["loss"] != r_async.history["loss"]


def test_hierarchical_single_edge_matches_flat():
    """E=1 collapses the two-tier tree: the lone edge computes the same
    weighted mean as flat FedAvg and the cloud averages one live edge, so
    the hierarchical lane must reproduce the flat trajectory (scatter-add
    vs jnp.sum reduction order aside) while paying the cheaper two-hop
    edge communication.  With E>1 and heterogeneous data sizes the cloud's
    UNWEIGHTED edge mean genuinely diverges from FedAvg — that is the
    plan's semantics, covered by test_staleness/mixed-frontier sanity."""
    fed = make_federated(2, "unsw", n_samples=600, n_clients=8)
    fl = FLConfig(n_clients=8, clients_per_round=8, rounds=4, local_epochs=2,
                  local_batch=16, local_lr=0.08, failure_prob=0.0,
                  hierarchy_edges=1)
    r_flat = fl_driver.run_fl_sweep(fed, fl, [{}], seeds=(0,), rounds=4,
                                    eval_every=2)[0][0]
    r_hier = fl_driver.run_fl_sweep(
        fed, fl, [{"plan": "hierarchical"}], seeds=(0,), rounds=4,
        eval_every=2)[0][0]
    np.testing.assert_allclose(np.asarray(r_hier.history["loss"]),
                               np.asarray(r_flat.history["loss"]), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(r_hier.history["auc"]),
                               np.asarray(r_flat.history["auc"]), atol=1e-5)
    # and the hierarchical time model is cheaper than the flat WAN hop
    assert r_hier.sim_time_s < r_flat.sim_time_s


def test_async_time_model_kth_arrival():
    """Direct simulate_round_time pin: the async wall is the K-th smallest
    selected arrival + comm, the sync wall the slowest + comm + FT terms."""
    from repro.core.selection import init_utility_state
    n = 6
    fl_sync = FLConfig(n_clients=n, fault_tolerance=False, dp_enabled=False)
    fl_async = FLConfig(n_clients=n, plan="buffered_async", async_buffer=2.0,
                        fault_tolerance=False, dp_enabled=False)
    util = init_utility_state(n, jax.random.key(0))
    util = util._replace(compute=jnp.ones((n,), jnp.float32))
    sel = jnp.ones((n,), jnp.float32)
    failed = jnp.zeros((n,), jnp.float32)
    slow = jnp.asarray([1.0, 1.0, 1.0, 1.0, 1.0, 10.0], jnp.float32)
    t_sync = float(fl_driver.simulate_round_time(fl_sync, util, sel, failed,
                                                 slow=slow))
    t_async = float(fl_driver.simulate_round_time(fl_async, util, sel, failed,
                                                  slow=slow))
    base = fl_sync.local_epochs * 0.02
    comm = 0.35 * (1.0 + 64.0 / 1024.0)
    assert t_sync == pytest.approx(10.0 * base + comm)
    assert t_async == pytest.approx(base + comm)  # 2nd arrival of 5 fast

    fl_hier = FLConfig(n_clients=n, plan="hierarchical",
                       fault_tolerance=False, dp_enabled=False)
    t_hier = float(fl_driver.simulate_round_time(fl_hier, util, sel, failed,
                                                 slow=slow))
    assert t_hier == pytest.approx(10.0 * base + 2.0 * 0.3 * comm)
