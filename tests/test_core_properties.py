"""Property-based tests (hypothesis) on the FL core's invariants
(deliverable c): DP mechanics, selection, fault math, aggregation, SSD
algebra.

``hypothesis`` is an optional test extra (``pip install -e .[test]``, see
pyproject.toml): the module skips cleanly when it is absent instead of
breaking collection of the whole suite."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis",
    reason="optional test extra 'hypothesis' not installed "
           "(pip install -e .[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.base import FLConfig
from repro.core import dp as dp_lib
from repro.core import fault as fault_lib
from repro.core import selection as sel_lib
from repro.core.aggregation import (aggregate_stacked, stream_accumulate,
                                    stream_finalize, stream_init)

SET = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# DP
# ---------------------------------------------------------------------------


@given(st.floats(0.1, 100.0), st.floats(1e-7, 1e-3))
@settings(**SET)
def test_gaussian_sigma_monotone_in_epsilon(eps, delta):
    """Less privacy budget -> more noise."""
    s1 = dp_lib.gaussian_sigma(eps, delta)
    s2 = dp_lib.gaussian_sigma(eps * 2, delta)
    assert s2 < s1


@given(st.floats(0.01, 50.0), st.integers(1, 64))
@settings(**SET)
def test_clip_bounds_global_norm(clip, n):
    x = {"a": jnp.linspace(-3, 7, n), "b": jnp.ones((n, 2)) * 2.5}
    clipped, norm = dp_lib.clip_by_global_norm(x, clip)
    out_norm = float(dp_lib.global_norm(clipped))
    assert out_norm <= clip * (1 + 1e-4)
    # no-op when already within the ball
    if float(norm) <= clip:
        for a, b in zip(jax.tree.leaves(clipped), jax.tree.leaves(x)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)


@given(st.floats(0.3, 5.0), st.integers(1, 300))
@settings(**SET)
def test_rdp_accountant_monotone_in_rounds(z, rounds):
    acc = dp_lib.RdpAccountant(1e-5)
    acc.step(z)
    e1 = acc.epsilon()
    for _ in range(rounds):
        acc.step(z)
    assert acc.epsilon() >= e1  # composition only loses privacy


@given(st.floats(0.05, 0.9))
@settings(**SET)
def test_subsampling_amplifies_privacy(q):
    full = dp_lib.rdp_gaussian(1.0)
    sub = dp_lib.rdp_subsampled_gaussian(1.0, q)
    assert (sub <= full + 1e-12).all()


def test_noise_multiplier_meets_budget():
    for eps in (2.0, 8.0, 32.0):
        z = dp_lib.noise_multiplier_for_budget(eps, 1e-5, 100, q=0.25)
        acc = dp_lib.RdpAccountant(1e-5)
        for _ in range(100):
            acc.step(z, 0.25)
        assert acc.epsilon() <= eps * 1.02


def test_privatize_noise_statistics():
    """Added noise must match the configured sigma distributionally."""
    key = jax.random.key(0)
    x = {"w": jnp.zeros((20_000,))}
    sigma = 0.37
    noised, _ = dp_lib.privatize_update(x, key, mode="clipped", clip=1.0,
                                        sigma=sigma)
    sd = float(jnp.std(noised["w"]))
    assert abs(sd - sigma) / sigma < 0.05


# ---------------------------------------------------------------------------
# Fault model
# ---------------------------------------------------------------------------


@given(st.floats(1.0, 5000.0), st.floats(0.5, 4.0))
@settings(**SET)
def test_weibull_prob_in_unit_interval_and_monotone(lam, k):
    ts = np.linspace(0.1, 10 * lam, 50)
    p = fault_lib.weibull_failure_prob(ts, lam, k)
    assert ((p >= 0) & (p <= 1)).all()
    assert (np.diff(p) >= -1e-12).all()


@given(st.floats(100.0, 5000.0), st.floats(0.6, 3.0), st.floats(0.5, 20.0))
@settings(**SET)
def test_optimal_interval_is_a_minimum(lam, k, w):
    """t_c* must be the minimum within the search bracket (the bracket caps
    at max(T, 4λ): an optimum pinned at the cap means 'checkpoint at most
    once per run', which is semantically correct when MTBF >> T)."""
    T, t_r = 3600.0, 30.0
    hi = max(T, 4.0 * lam)
    tc = fault_lib.optimal_checkpoint_interval(T, t_r, lam, k, write_cost=w)
    assert 0 < tc <= hi * (1 + 1e-6)
    c_star = fault_lib.checkpoint_cost(tc, T, t_r, lam, k, w)
    for factor in (0.5, 2.0):
        other = tc * factor
        if not (1e-3 <= other <= hi):
            continue  # outside the bracket: boundary optimum is allowed
        c_other = fault_lib.checkpoint_cost(other, T, t_r, lam, k, w)
        assert c_star <= c_other * (1 + 1e-6)


@given(st.lists(st.floats(1.0, 1000.0), min_size=30, max_size=200))
@settings(**SET)
def test_weibull_fit_positive(samples):
    lam, k = fault_lib.fit_weibull(samples)
    assert lam > 0 and k > 0


def test_weibull_fit_recovers_parameters():
    rng = np.random.default_rng(3)
    for true_k in (0.8, 1.5, 2.5):
        x = 200.0 * rng.weibull(true_k, 4000)
        lam, k = fault_lib.fit_weibull(x)
        assert abs(k - true_k) / true_k < 0.1
        assert abs(lam - 200.0) / 200.0 < 0.1


# ---------------------------------------------------------------------------
# Selection
# ---------------------------------------------------------------------------


@given(st.integers(4, 64), st.integers(1, 10), st.sampled_from(
    list(sel_lib.strategy_names())))
@settings(**SET)
def test_selection_respects_k_and_availability(n, k, strat_name):
    k = min(k, n)
    fl = FLConfig(n_clients=n, clients_per_round=k)
    state = sel_lib.init_utility_state(n, key=jax.random.key(0))
    util = sel_lib.compute_utility(state, fl)
    avail = (jnp.arange(n) % 2 == 0).astype(jnp.float32)  # half available
    strat = sel_lib.get_strategy(strat_name)
    mask = strat(jax.random.key(1), state, util, avail,
                 jnp.asarray(float(k)), k)
    m = np.asarray(mask)
    assert ((m == 0) | (m == 1)).all()
    assert m.sum() <= k
    assert (m * (1 - np.asarray(avail)) == 0).all(), "selected unavailable client"


@given(st.integers(4, 40))
@settings(**SET)
def test_adaptive_k_grows_on_plateau_shrinks_on_improvement(n):
    fl = FLConfig(n_clients=n, clients_per_round=max(2, n // 4), k_min=2)
    ks = sel_lib.init_k_state(fl)
    k0 = float(ks.k)
    # strong improvement -> K shrinks (or stays at k_min)
    ks2 = sel_lib.update_k(ks, jnp.asarray(0.5), fl)
    ks2 = sel_lib.update_k(ks2._replace(best_metric=jnp.asarray(0.5)),
                           jnp.asarray(0.25), fl)
    assert float(ks2.k) <= k0
    # plateau -> K grows
    ks3 = ks._replace(best_metric=jnp.asarray(1.0))
    for _ in range(4):
        ks3 = sel_lib.update_k(ks3, jnp.asarray(1.0), fl)
    assert float(ks3.k) > k0 or float(ks3.k) == float(fl.k_max or n)


def test_utility_update_only_touches_selected():
    fl = FLConfig(n_clients=6)
    s = sel_lib.init_utility_state(6, key=jax.random.key(0))
    mask = jnp.array([1, 0, 1, 0, 0, 0], jnp.float32)
    pre = jnp.full((6,), 2.0)
    post = jnp.full((6,), 1.0)
    s2 = sel_lib.update_utility_state(s, mask, pre, post, fl)
    np.testing.assert_allclose(np.asarray(s2.perf_ema)[[1, 3, 4, 5]],
                               np.asarray(s.perf_ema)[[1, 3, 4, 5]])
    assert (np.asarray(s2.perf_ema)[[0, 2]] > 0).all()
    np.testing.assert_allclose(np.asarray(s2.participation),
                               np.asarray(mask))


# ---------------------------------------------------------------------------
# Aggregation
# ---------------------------------------------------------------------------


@given(st.integers(2, 12))
@settings(**SET)
def test_stacked_and_streamed_aggregation_agree(n):
    key = jax.random.key(n)
    deltas = {"w": jax.random.normal(key, (n, 5, 3)),
              "b": jax.random.normal(jax.random.fold_in(key, 1), (n, 4))}
    mask = (jax.random.uniform(jax.random.fold_in(key, 2), (n,)) > 0.4).astype(
        jnp.float32)
    if float(mask.sum()) == 0:
        mask = mask.at[0].set(1.0)
    weights = jax.random.uniform(jax.random.fold_in(key, 3), (n,), minval=0.5,
                                 maxval=2.0)
    stacked = aggregate_stacked(deltas, mask, weights)

    carry = stream_init(jax.tree.map(lambda x: x[0], deltas))
    for i in range(n):
        carry = stream_accumulate(carry, jax.tree.map(lambda x: x[i], deltas),
                                  mask[i], weights[i])
    streamed = stream_finalize(carry)
    for a, b in zip(jax.tree.leaves(stacked), jax.tree.leaves(streamed)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                                   atol=2e-6)


def test_aggregation_unselected_clients_have_no_influence():
    n = 5
    deltas = {"w": jnp.stack([jnp.full((3,), float(i)) for i in range(n)])}
    mask = jnp.array([1, 1, 0, 0, 0], jnp.float32)
    agg = aggregate_stacked(deltas, mask, jnp.ones((n,)))
    np.testing.assert_allclose(np.asarray(agg["w"]), 0.5)


# ---------------------------------------------------------------------------
# SSD algebra (chunk-size invariance = the state-passing identity)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("chunks", [(8, 32), (16, 64), (32, 64)])
def test_ssd_chunk_size_invariance(chunks):
    from repro.models.ssm import ssd_chunked

    b, l, h, p, n = 2, 64, 3, 8, 16
    key = jax.random.key(0)
    x = jax.random.normal(key, (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (b, l, h)))
    A = jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (h,)) * 0.3)
    B = jax.random.normal(jax.random.fold_in(key, 3), (b, l, n))
    C = jax.random.normal(jax.random.fold_in(key, 4), (b, l, n))
    y1, s1 = ssd_chunked(x, dt, A, B, C, chunks[0])
    y2, s2 = ssd_chunked(x, dt, A, B, C, chunks[1])
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4,
                               rtol=1e-4)


def test_ssd_chunked_matches_sequential_recurrence():
    """SSD dual form == naive recurrent form."""
    from repro.models.ssm import ssd_chunked

    b, l, h, p, n = 1, 32, 2, 4, 8
    key = jax.random.key(1)
    x = jax.random.normal(key, (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (b, l, h)))
    A = jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (h,)) * 0.3)
    B = jax.random.normal(jax.random.fold_in(key, 3), (b, l, n))
    C = jax.random.normal(jax.random.fold_in(key, 4), (b, l, n))
    y, _ = ssd_chunked(x, dt, A, B, C, 16)

    # naive recurrence
    s = np.zeros((b, h, p, n))
    ys = np.zeros((b, l, h, p))
    xn, dtn, An, Bn, Cn = map(np.asarray, (x, dt, A, B, C))
    for t in range(l):
        dA = np.exp(-An * dtn[:, t])  # [b,h]
        s = s * dA[:, :, None, None] + np.einsum(
            "bh,bhp,bn->bhpn", dtn[:, t], xn[:, t], Bn[:, t])
        ys[:, t] = np.einsum("bn,bhpn->bhp", Cn[:, t], s)
    np.testing.assert_allclose(np.asarray(y), ys, atol=1e-4, rtol=1e-4)
