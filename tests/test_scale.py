"""Population-engine tests (ISSUE 6): on-device cohort sampling, the
lane × client mesh, and the DESIGN.md §7 memory budget.

* property tests — the sharded top-k cohort is a permutation-free subset
  of size ceil(k_eff) drawn from the available clients; at small N it
  matches the host-side NumPy reference draw BITWISE (same tie-breaking);
  at large N selection frequencies track the utility ordering.  Driven by
  hypothesis when it is installed (CI), by seeded random sweeps otherwise
  (this container has no hypothesis wheel) — the cases run either way.
* chunked selection — ``cohort_topk(chunks=c)`` is bitwise the unchunked
  selection for every divisor chunking, and the driver's
  ``memory_budget_bytes`` auto-chunk policy crosses the 1 → >1 boundary
  without moving a bit of the results.
* sharding equivalence — subprocess with 4 XLA-faked CPU devices: the
  population engine on (4,1)/(2,2)/(1,4) lane×client meshes and the dense
  sweep engine on its 1-D lane mesh reproduce the single-device run.  All
  state-carrying history columns (acc/auc/k/fail/cum_time/eps) must match
  BITWISE; the scalar ``loss`` column is reduction-order sensitive under
  GSPMD partitioning and gets a tight tolerance instead.
* memory budget — the ``core/scale.py`` §7 formulas are pinned against
  the real carry NamedTuples, the real Population buffers, and the
  compiled runner's measured ``memory_analysis()`` argument bytes.
* single compile — one runner-cache miss per population shape, hits
  thereafter (RUNNER_STATS, same discipline as the sweep engine).
"""
import math
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core import scale as scale_lib
from repro.core import selection as sel_lib
from repro.data.synthetic import (Population, make_population,
                                  sample_cohort_batches)
from repro.fault.process import FaultState, init_fault_state
from repro.train import fl_driver

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # local containers without the wheel: seeded sweeps
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# property: the on-device cohort draw
# ---------------------------------------------------------------------------


def _random_case(rng, n_max=20):
    n = int(rng.integers(2, n_max + 1))
    k_max = int(rng.integers(1, n + 1))
    k_eff = float(rng.uniform(0.0, k_max + 1.0))
    scores = rng.normal(size=n).astype(np.float32)
    if rng.random() < 0.3:  # force ties: tie-breaking must match too
        scores[: n // 2] = scores[0]
    avail = (rng.random(n) < 0.8).astype(np.float32)
    return n, k_max, k_eff, scores, avail


def _check_cohort_invariants(n, k_max, k_eff, scores, avail):
    idx, take = sel_lib.cohort_topk(jnp.asarray(scores), jnp.asarray(avail),
                                    jnp.asarray(k_eff, jnp.float32), k_max)
    idx, take = np.asarray(idx), np.asarray(take)
    taken = idx[take > 0]
    # a permutation-free subset: no client occupies two live slots
    assert len(np.unique(taken)) == len(taken)
    # only available clients are ever taken
    assert all(avail[i] > 0 for i in taken)
    # exactly ceil(k_eff) live slots, capped by k_max and availability
    expect = min(int(math.ceil(k_eff)), k_max, int(avail.sum()))
    assert len(taken) == expect, (len(taken), expect, k_eff, k_max)
    # bitwise the host-side reference draw (same tie-breaking)
    h_idx, h_take = sel_lib.cohort_topk_host(scores, avail, k_eff, k_max)
    np.testing.assert_array_equal(idx, h_idx)
    np.testing.assert_array_equal(take, h_take)
    # the index form reproduces the dense _topk_mask exactly
    dense = np.zeros(n, np.float32)
    np.add.at(dense, idx, take)
    mask = np.asarray(sel_lib._topk_mask(
        jnp.asarray(scores), jnp.asarray(avail),
        jnp.asarray(k_eff, jnp.float32), k_max))
    np.testing.assert_array_equal(dense, mask)


if HAVE_HYPOTHESIS:

    @settings(deadline=None, max_examples=60)
    @given(st.integers(0, 2**31 - 1))
    def test_cohort_topk_matches_host_reference(case_seed):
        _check_cohort_invariants(
            *_random_case(np.random.default_rng(case_seed)))

else:

    def test_cohort_topk_matches_host_reference():
        rng = np.random.default_rng(0)
        for _ in range(60):
            _check_cohort_invariants(*_random_case(rng))


@pytest.mark.parametrize("chunks", [2, 4, 8, 16])
def test_chunked_topk_bitwise_equals_unchunked(chunks):
    rng = np.random.default_rng(1)
    n, k_max = 128, 8
    for _ in range(20):
        scores = rng.normal(size=n).astype(np.float32)
        scores[:16] = scores[0]  # ties across chunk boundaries
        avail = (rng.random(n) < 0.85).astype(np.float32)
        k_eff = float(rng.uniform(0, k_max))
        i1, t1 = sel_lib.cohort_topk(jnp.asarray(scores), jnp.asarray(avail),
                                     k_eff, k_max, chunks=1)
        ic, tc = sel_lib.cohort_topk(jnp.asarray(scores), jnp.asarray(avail),
                                     k_eff, k_max, chunks=chunks)
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(ic))
        np.testing.assert_array_equal(np.asarray(t1), np.asarray(tc))


def test_selection_frequency_tracks_utility():
    """At large N, clients with higher utility must be selected more often
    under the adaptive-utility score (exploration noise jitters ranks but
    cannot invert the ordering in aggregate)."""
    n, k_max, draws = 512, 32, 200
    rng = np.random.default_rng(2)
    utility = jnp.asarray(rng.normal(size=n).astype(np.float32))
    avail = jnp.ones((n,), jnp.float32)
    counts = np.zeros(n)
    for d in range(draws):
        scores = sel_lib.score_adaptive_utility(
            jax.random.key(d), None, utility, avail, explore=0.5)
        idx, take = sel_lib.cohort_topk(scores, avail, float(k_max), k_max)
        counts[np.asarray(idx)[np.asarray(take) > 0]] += 1
    freq = counts / draws
    order = np.argsort(np.asarray(utility))
    top, bottom = freq[order[-64:]].mean(), freq[order[:64]].mean()
    assert top > 10 * max(bottom, 1e-3), (top, bottom)
    # rank correlation, not just the extremes
    ranks_u = np.argsort(np.argsort(np.asarray(utility)))
    ranks_f = np.argsort(np.argsort(freq))
    corr = np.corrcoef(ranks_u, ranks_f)[0, 1]
    assert corr > 0.6, corr


def test_cohort_batches_are_the_clients_own_data():
    """The gathered batches must come from each cohort client's membership
    rows (pool rows + that client's deterministic covariate shift)."""
    pop = make_population(3, n_clients=32, pool_samples=400,
                          members_per_client=8)
    cohort = jnp.asarray([5, 17, 2, 30], jnp.int32)
    b = sample_cohort_batches(jax.random.key(0), pop, cohort, 2, 6)
    assert b["x"].shape == (4, 2, 6, pop.n_features)
    assert b["y"].shape == (4, 2, 6)
    pool_x = np.asarray(pop.pool_x)
    pool_y = np.asarray(pop.pool_y)
    for s, ci in enumerate(np.asarray(cohort)):
        members = set(np.asarray(pop.member_idx)[ci].tolist())
        shift = pop.feature_shift * np.asarray(jax.random.normal(
            jax.random.fold_in(pop.shift_key, int(ci)),
            (pop.n_features,)))
        xs = np.asarray(b["x"][s]).reshape(-1, pop.n_features) - shift
        ys = np.asarray(b["y"][s]).reshape(-1)
        for row, label in zip(xs, ys):
            dists = np.abs(pool_x - row).sum(1)
            j = int(np.argmin(dists))
            assert dists[j] < 1e-4, "batch row is not a shifted pool row"
            assert j in members, "batch row drawn outside the client's shard"
            assert pool_y[j] == label


# ---------------------------------------------------------------------------
# engine: single compile, auto-chunk boundary
# ---------------------------------------------------------------------------


def _small_fl(**kw):
    base = dict(n_clients=64, clients_per_round=8, k_max=8, rounds=6,
                local_epochs=2, local_batch=16, local_lr=0.08,
                fault_tolerance=True, failure_prob=0.05)
    base.update(kw)
    return FLConfig(**base)


@pytest.fixture(scope="module")
def pop64():
    return make_population(0, n_clients=64, pool_samples=600,
                           members_per_client=16)


def test_population_single_compile(pop64):
    fl = _small_fl()
    m0 = fl_driver.RUNNER_STATS["misses"]
    r1 = fl_driver.run_fl_population(pop64, fl, seeds=(0, 1), rounds=6,
                                     eval_every=3)
    assert fl_driver.RUNNER_STATS["misses"] - m0 == 1
    # runtime-only change: cache hit, and the runtime value reaches the math
    r2 = fl_driver.run_fl_population(
        pop64, fl, params_grid=[{"failure_prob": 0.6}], seeds=(0, 1),
        rounds=6, eval_every=3)
    assert fl_driver.RUNNER_STATS["misses"] - m0 == 1
    assert r1[0][0].history["loss"] != r2[0][0].history["loss"]


def test_population_rejects_fedl2p_and_dense_kmax(pop64):
    with pytest.raises(ValueError, match="fedl2p"):
        fl_driver.run_fl_population(pop64, _small_fl(), method="fedl2p")
    with pytest.raises(ValueError, match="k_max"):
        fl_driver.run_fl_population(pop64, _small_fl(k_max=0))


def test_auto_chunk_boundary_bitwise(pop64):
    """A budget just above the resident floor forces >1 selection chunks; a
    generous budget stays at 1 chunk — and the results are bitwise equal,
    because chunking only reshapes the selection working set."""
    n, m, lanes = 64, 16, 2
    fl = _small_fl()
    # resident now includes one model replica per lane (ISSUE 10) — budget
    # with the same spec.param_bytes() the driver feeds auto_chunks
    from repro.models.spec import get_model_spec, meta_for
    mb = get_model_spec(fl.model, meta_for(pop64, hidden=64)).param_bytes()
    resident = scale_lib.population_resident_bytes(n, m, lanes, mb)
    transient = scale_lib.selection_transient_bytes(n)
    tight = resident + transient // 4          # forces ceil(transient/free) > 1
    roomy = resident + 10 * transient
    assert scale_lib.auto_chunks(n, roomy, m, lanes, model_bytes=mb) == 1
    assert scale_lib.auto_chunks(n, tight, m, lanes, model_bytes=mb) > 1
    with pytest.raises(ValueError, match="resident"):
        scale_lib.auto_chunks(n, resident, m, lanes, model_bytes=mb)
    r1 = fl_driver.run_fl_population(pop64, fl, seeds=(0, 1), rounds=6,
                                     eval_every=3,
                                     memory_budget_bytes=roomy)
    r2 = fl_driver.run_fl_population(pop64, fl, seeds=(0, 1), rounds=6,
                                     eval_every=3,
                                     memory_budget_bytes=tight)
    for si in range(2):
        assert r1[0][si].history == r2[0][si].history


# ---------------------------------------------------------------------------
# memory budget: §7 formulas vs real buffers
# ---------------------------------------------------------------------------


def test_carry_field_counts_pinned_to_real_state():
    """The §7 accounting counts 11 UtilityState + 2 FaultState [N] f32
    carries — pin those against the actual NamedTuples so the formulas
    cannot silently rot when a field is added."""
    n = 7
    util = sel_lib.init_utility_state(n, key=jax.random.key(0))
    fault = init_fault_state(n)
    u_vecs = [x for x in util if x.shape == (n,) and x.dtype == jnp.float32]
    f_vecs = [x for x in fault if x.shape == (n,) and x.dtype == jnp.float32]
    assert len(u_vecs) == len(util) == scale_lib.UTILITY_STATE_FIELDS
    assert len(f_vecs) == len(fault) == scale_lib.FAULT_STATE_FIELDS
    assert scale_lib.CARRY_FIELDS == 13
    assert scale_lib.population_carry_bytes(n) == sum(
        x.nbytes for x in u_vecs + f_vecs)


def test_population_data_bytes_matches_real_population():
    pop = make_population(0, n_clients=48, pool_samples=400,
                          members_per_client=12)
    per_client = (pop.member_idx, pop.member_size, pop.data_size,
                  pop.data_quality)
    assert scale_lib.population_data_bytes(48, 12) == sum(
        np.asarray(x).nbytes for x in per_client)


def test_compiled_runner_memory_analysis(pop64):
    """XLA's own measurement of the compiled population program's inputs
    must equal the byte total of the real argument buffers — which the §7
    formulas in turn predict for the per-client terms.  (On CPU,
    ``temp_size_in_bytes`` is reported as 0, so the argument account is
    the honest measurable quantity.)"""
    fl = fl_driver.fl_for_method(_small_fl(), "proposed")
    from repro.models.spec import meta_for
    meta = meta_for(pop64, hidden=64)
    runner = fl_driver._get_population_runner(fl, 6, 3, meta, 2, pop64, 1)
    keys = jax.vmap(jax.random.key)(jnp.asarray([0, 1], jnp.uint32))
    lanes = fl_driver._params_lanes([fl], 2)
    mem = runner.lower(keys, pop64, lanes).compile().memory_analysis()

    def nbytes(x):
        if jnp.issubdtype(x.dtype, jax.dtypes.prng_key):
            x = jax.random.key_data(x)  # key arrays hide their uint32 words
        return np.asarray(x).nbytes

    expected = sum(nbytes(x) for x in jax.tree.leaves((keys, pop64, lanes)))
    # XLA elides runtime scalar lanes this static config never reads, so
    # the measured account may fall short of the handed-over buffers by at
    # most the FLParams lane bytes — the population/pool/test arrays (all
    # the N-scaled terms) must be measured exactly
    lane_bytes = sum(nbytes(x) for x in jax.tree.leaves(lanes))
    assert expected - lane_bytes <= mem.argument_size_in_bytes <= expected
    # the §7 per-client account is part of that total
    per_client = scale_lib.population_data_bytes(
        pop64.n_clients, pop64.members_per_client)
    assert per_client < expected
    assert per_client == sum(
        np.asarray(x).nbytes for x in
        (pop64.member_idx, pop64.member_size, pop64.data_size,
         pop64.data_quality))


def test_selection_transient_formula():
    assert scale_lib.selection_transient_bytes(1000) == 4 * 1000 * 4
    assert scale_lib.selection_transient_bytes(1000, 4) == 4 * 250 * 4
    # chunking shrinks ONLY the transient term, never the resident terms
    assert (scale_lib.population_resident_bytes(1000, 32, 2)
            == scale_lib.population_data_bytes(1000, 32)
            + 2 * scale_lib.population_carry_bytes(1000))


# ---------------------------------------------------------------------------
# sharding equivalence: lane × client mesh vs single device (subprocess)
# ---------------------------------------------------------------------------

_SHARD_SCRIPT = r"""
import dataclasses, jax, numpy as np
assert len(jax.devices()) == 4, jax.devices()
from repro.configs.base import FLConfig
from repro.data.synthetic import make_federated, make_population
from repro.train import fl_driver

SEEDS = (0, 1, 2, 3)

def compare(ref_rows, sh_rows, tag):
    for ref, sh in zip(ref_rows, sh_rows):
        for col in ref.history:
            a, b = ref.history[col], sh.history[col]
            if col == "loss":
                # the one reduction-order-sensitive scalar under GSPMD
                np.testing.assert_allclose(a, b, atol=5e-5, err_msg=tag)
            else:
                assert a == b, (tag, col, a, b)

# --- population engine (client_cohort plan) on lane x client meshes -------
pop = make_population(0, n_clients=64, pool_samples=600,
                      members_per_client=16)
fl = FLConfig(n_clients=64, clients_per_round=8, k_max=8, rounds=6,
              local_epochs=2, local_batch=16, fault_tolerance=True,
              failure_prob=0.05)
ref = fl_driver.run_fl_population(pop, fl, seeds=SEEDS, rounds=6,
                                  eval_every=3, shard=False)[0]
for shape in [(4, 1), (2, 2), (1, 4)]:
    sh = fl_driver.run_fl_population(pop, fl, seeds=SEEDS, rounds=6,
                                     eval_every=3, mesh_shape=shape)[0]
    compare(ref, sh, f"population mesh {shape}")

# scheduled-privacy carry (accountant state) must survive sharding too
fl_dp = dataclasses.replace(fl, dp_enabled=True, dp_scheduled=True,
                            dp_mode="clipped", adaptive_k=True)
ref = fl_driver.run_fl_population(pop, fl_dp, seeds=SEEDS, rounds=6,
                                  eval_every=3, shard=False)[0]
sh = fl_driver.run_fl_population(pop, fl_dp, seeds=SEEDS, rounds=6,
                                 eval_every=3, mesh_shape=(2, 2))[0]
compare(ref, sh, "population scheduled (2,2)")
assert all(r.history["eps"] == s.history["eps"] for r, s in zip(ref, sh))

# --- dense sweep engine (client_parallel plan) on its 1-D lane mesh -------
fed = make_federated(0, "unsw", n_samples=800, n_clients=8)
fl_d = FLConfig(n_clients=8, clients_per_round=3, rounds=6, local_epochs=2,
                local_batch=16, dp_enabled=True, dp_mode="clipped",
                dp_epsilon=300.0, dp_clip=5.0, fault_tolerance=True)
cells = [dataclasses.replace(fl_d, dp_epsilon=e) for e in (100.0, 300.0)]
sharded = fl_driver.run_fl_sweep(fed, fl_d, cells, seeds=(0, 1), rounds=6,
                                 eval_every=3)
orig = fl_driver._lane_sharding
fl_driver._lane_sharding = lambda n: None      # same lane count, no mesh
try:
    unsharded = fl_driver.run_fl_sweep(fed, fl_d, cells, seeds=(0, 1),
                                       rounds=6, eval_every=3)
finally:
    fl_driver._lane_sharding = orig
for ci in range(2):
    compare(unsharded[ci], sharded[ci], f"dense sweep cell {ci}")
print("SHARD_EQUIV_OK")
"""


def test_sharded_engines_match_single_device(tmp_path):
    """4 XLA-faked CPU devices: both round plans — the dense
    client_parallel sweep on its 1-D lane mesh and the population
    client_cohort plan on (4,1)/(2,2)/(1,4) lane×client meshes — must
    reproduce the single-device run: every state-carrying history column
    bitwise, the loss scalar within reduction-order tolerance.  Subprocess
    because the device count must be faked before jax initialises."""
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARD_EQUIV_OK" in out.stdout
