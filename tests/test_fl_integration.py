"""Integration tests: full FL rounds end-to-end (both plans), DP modes,
fault-tolerance semantics, checkpoint round-trips, and convergence on the
anomaly-detection use case."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig, get_arch
from repro.core import rounds as rounds_lib
from repro.data.synthetic import make_federated, round_batches
from repro.data.tokens import lm_round_batches
from repro.models import mlp as mlp_lib
from repro.models.model import build


def _fl(**kw):
    base = FLConfig(n_clients=10, clients_per_round=4, local_epochs=1,
                    local_batch=16, local_lr=0.08, dp_enabled=False,
                    failure_prob=0.0)
    return dataclasses.replace(base, **kw)


@pytest.fixture(scope="module")
def fed():
    return make_federated(0, "unsw", n_samples=3_000, n_clients=10)


def _mlp_setup(fed, fl, seed=0):
    params = mlp_lib.init_mlp(jax.random.key(seed), fed.n_features, 32, 2)
    state = rounds_lib.init_round_state(params, fl, jax.random.key(seed + 1),
                                        n_clients=fed.n_clients)
    return params, state


def _batches(fed, fl, steps=4, seed=0):
    rng = np.random.default_rng(seed)
    return jax.tree.map(jnp.asarray, round_batches(rng, fed, steps, fl.local_batch))


def test_parallel_round_converges(fed):
    fl = _fl()
    params, state = _mlp_setup(fed, fl)
    step = jax.jit(rounds_lib.make_parallel_round(mlp_lib.mlp_loss, fl, 10))
    losses = []
    for r in range(12):
        state, m = step(state, _batches(fed, fl, seed=r))
        losses.append(float(m.global_loss))
    assert losses[-1] < losses[0] * 0.9, losses
    acc = float(mlp_lib.accuracy(state.params, jnp.asarray(fed.test_x),
                                 jnp.asarray(fed.test_y)))
    assert acc > 0.8


def test_serial_round_matches_semantics(fed):
    """client_serial with K slots must also converge and produce
    identically-structured state."""
    fl = _fl(serial_clients_in_step=3)
    params, state = _mlp_setup(fed, fl)
    step = jax.jit(rounds_lib.make_serial_round(mlp_lib.mlp_loss, fl, 10))
    for r in range(10):
        b = _batches(fed, fl, seed=r)
        b3 = jax.tree.map(lambda x: x[:3], b)
        state, m = step(state, b3)
    assert float(m.global_loss) < 0.7
    assert state.params["l1"]["w"].shape == params["l1"]["w"].shape


def test_dp_noise_shrinks_with_epsilon(fed):
    """Smaller epsilon -> more noise -> worse (or equal) convergence."""
    def final_loss(eps):
        fl = _fl(dp_enabled=True, dp_mode="clipped", dp_epsilon=eps, dp_clip=2.0)
        _, state = _mlp_setup(fed, fl)
        step = jax.jit(rounds_lib.make_parallel_round(mlp_lib.mlp_loss, fl, 10))
        for r in range(10):
            state, m = step(state, _batches(fed, fl, seed=r))
        return float(m.global_loss)

    noisy = final_loss(0.5)
    clean = final_loss(500.0)
    assert clean < noisy + 0.05, (clean, noisy)


def test_dp_paper_mode_runs(fed):
    fl = _fl(dp_enabled=True, dp_mode="paper", dp_sigma=0.01)
    _, state = _mlp_setup(fed, fl)
    step = jax.jit(rounds_lib.make_parallel_round(mlp_lib.mlp_loss, fl, 10))
    state, m = step(state, _batches(fed, fl))
    assert np.isfinite(float(m.global_loss))


def test_fault_tolerance_keeps_failed_clients_contributing(fed):
    """At high failure rates, FT must retain more contributors than no-FT."""
    def contributors(ft):
        fl = _fl(failure_prob=0.9, fault_tolerance=ft, clients_per_round=8,
                 adaptive_k=False)
        _, state = _mlp_setup(fed, fl)
        step = jax.jit(rounds_lib.make_parallel_round(mlp_lib.mlp_loss, fl, 10,
                                                      ckpt_every_steps=1))
        tot = 0.0
        for r in range(5):
            state, m = step(state, _batches(fed, fl, steps=4, seed=r))
            tot += float(m.sel_mask.sum())
        return tot

    with_ft = contributors(True)
    without = contributors(False)
    assert with_ft >= without


def test_checkpoint_roundtrip_restores_training(fed, tmp_path):
    from repro.checkpoint.checkpoint import Checkpointer

    fl = _fl()
    _, state = _mlp_setup(fed, fl)
    step = jax.jit(rounds_lib.make_parallel_round(mlp_lib.mlp_loss, fl, 10))
    state, _ = step(state, _batches(fed, fl))
    ck = Checkpointer(str(tmp_path), interval_rounds=1)
    ck.maybe_save(1, state.params)
    rnd, restored = ck.restore_latest(state.params)
    assert rnd == 1
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fl_round_on_assigned_architecture():
    """The FL engine must run the *assigned architectures*, not just the
    MLP: one serial round on the reduced mamba2 + one on reduced granite."""
    for arch in ("mamba2_130m", "granite_3_8b"):
        cfg = get_arch(arch, smoke=True)
        model = build(cfg)
        fl = _fl(n_clients=8, serial_clients_in_step=2, local_lr=0.01)
        params = model.init(jax.random.key(0))
        state = rounds_lib.init_round_state(params, fl, jax.random.key(1),
                                            n_clients=8)
        loss_fn = lambda p, b: model.loss(p, b, remat="none")
        step = jax.jit(rounds_lib.make_serial_round(loss_fn, fl, 8))
        data = lm_round_batches(cfg.vocab_size, 2, 1, 2, 16, seed=0)
        batches = jax.tree.map(jnp.asarray, data)
        state, m = step(state, batches)
        assert np.isfinite(float(m.global_loss)), arch
        # params must have moved
        moved = any(
            bool(jnp.any(a != b))
            for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(params))
        )
        assert moved, arch


def test_microbatched_grads_match_full_batch():
    """grad_accum must be numerically equivalent to the full batch."""
    fed = make_federated(1, "unsw", n_samples=600, n_clients=4)
    params = mlp_lib.init_mlp(jax.random.key(0), fed.n_features, 16, 2)
    batch = {"x": jnp.asarray(fed.test_x[:32]), "y": jnp.asarray(fed.test_y[:32])}
    l1, g1 = jax.value_and_grad(mlp_lib.mlp_loss)(params, batch)
    vag = rounds_lib.microbatched_value_and_grad(mlp_lib.mlp_loss, 4)
    l2, g2 = vag(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
