"""Observability layer (ISSUE 8): tracer, stats registry, experiment
store, regression gate, schema checker.

The hard property is **bitwise neutrality**: turning the tracer on must
not change a single bit of ``run_fl_batch``'s outputs — spans time host
phases only, and the device-side markers are ``jax.named_scope`` metadata.
The rest is the store/gate machinery ``benchmarks/common.record_bench``
and ``tools/bench_regress.py`` are built on.
"""
from __future__ import annotations

import json
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.data.synthetic import make_federated
from repro.obs import TRACER, profile_trace  # noqa: F401 — re-export check
from repro.obs.stats import StatsRegistry
from repro.obs.store import ExperimentStore
from repro.obs.trace import Tracer
from repro.train import fl_driver

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT))          # tools/ + benchmarks/ imports
sys.path.insert(0, str(ROOT / "tools"))

import bench_regress  # noqa: E402
import check_bench_schema  # noqa: E402


# ---------------------------------------------------------------------------
# tracer: nesting, timing, events, zero-cost-off
# ---------------------------------------------------------------------------

def test_spans_nest_with_depth_and_parent():
    tr = Tracer()
    tr.enable()
    with tr.span("outer", k=1):
        with tr.span("inner"):
            pass
        with tr.span("inner"):
            tr.event("tick", n=7)
    outer = tr.find("outer")[0]
    inners = tr.find("inner")
    assert len(inners) == 2
    assert outer.depth == 0 and outer.parent == -1
    assert all(s.depth == 1 and s.parent == outer.index for s in inners)
    assert outer.wall_s >= max(s.wall_s for s in inners) >= 0.0
    assert outer.attrs == {"k": 1}
    (ev,) = tr.events
    assert ev["name"] == "tick" and ev["n"] == 7 and ev["depth"] == 2


def test_disabled_tracer_records_nothing_and_returns_shared_noop():
    tr = Tracer()
    cm1, cm2 = tr.span("a"), tr.span("b")
    assert cm1 is cm2                      # shared null object, no alloc
    with tr.span("a"):
        tr.event("e")
    assert tr.spans == [] and tr.events == []


def test_jsonl_dump_round_trips(tmp_path):
    tr = Tracer()
    tr.enable()
    with tr.span("phase", rep=0):
        tr.event("compile", engine="sweep")
    path = tr.dump_jsonl(str(tmp_path / "trace.jsonl"))
    rows = [json.loads(ln) for ln in Path(path).read_text().splitlines()]
    kinds = {r["type"] for r in rows}
    assert kinds == {"span", "event"} and len(rows) == 2
    sp = next(r for r in rows if r["type"] == "span")
    assert sp["name"] == "phase" and sp["rep"] == 0 and sp["wall_s"] >= 0


# ---------------------------------------------------------------------------
# stats registry: dict-compat views, delta/expect/reset
# ---------------------------------------------------------------------------

def test_counters_behave_like_the_dicts_they_replaced():
    reg = StatsRegistry()
    stats = reg.counters("runner", misses=0, hits=0)
    m0 = stats["misses"]
    stats["misses"] += 1
    stats["hits"] += 3
    assert stats["misses"] - m0 == 1
    assert dict(stats) == {"misses": 1, "hits": 3}
    assert reg.counters("runner") is stats     # module aliases stay views
    reg.reset("runner")
    assert dict(stats) == {"misses": 0, "hits": 0}


def test_registry_delta_and_expect():
    reg = StatsRegistry()
    st = reg.counters("ns", a=0, b=0)
    with reg.delta("ns") as d:
        st["a"] += 2
    assert d == {"a": 2, "b": 0}
    with reg.expect("ns", a=1):
        st["a"] += 1
    with pytest.raises(AssertionError):
        with reg.expect("ns", a=1):
            pass                                # no move -> delta 0 != 1


def test_live_registries_are_registered_namespaces():
    from repro.obs.stats import STATS
    from repro.serve import engine as serve_engine

    snap = STATS.snapshot()
    assert "runner" in snap and "serve" in snap
    assert dict(fl_driver.RUNNER_STATS) == snap["runner"]
    assert dict(serve_engine.SERVE_STATS) == snap["serve"]


# ---------------------------------------------------------------------------
# bitwise neutrality: tracer on == tracer off
# ---------------------------------------------------------------------------

def test_telemetry_is_bitwise_neutral():
    fed = make_federated(0, "unsw", n_samples=600, n_clients=6)
    fl = FLConfig(n_clients=6, clients_per_round=3, rounds=4, local_epochs=2,
                  local_batch=32, local_lr=0.1, dp_enabled=True,
                  dp_mode="clipped", dp_epsilon=1000.0, dp_clip=1.0,
                  fault_tolerance=True, failure_prob=0.1)

    def go():
        fl_driver._RUNNER_CACHE.clear()
        res = fl_driver.run_fl_batch(fed, fl, "proposed", seeds=(0, 1),
                                     rounds=4, eval_every=2)
        return [(r.accuracy, r.auc, r.eps_spent,
                 tuple(np.asarray(r.history["acc"]).tolist())) for r in res]

    was = TRACER.enabled
    TRACER.disable()
    off = go()
    TRACER.enable()
    try:
        on = go()
        assert TRACER.find("runner.build"), "instrumented build span missing"
        assert TRACER.find("sweep.execute"), "execute span missing"
        assert any(e["name"] == "compile.runner_miss" for e in TRACER.events)
    finally:
        TRACER.disable()
        TRACER.clear()
        if was:
            TRACER.enable()
    assert on == off, "telemetry changed the engine's outputs"


# ---------------------------------------------------------------------------
# experiment store: round-trip + indexed queries
# ---------------------------------------------------------------------------

def _tiny_store(tmp_path, n_runs=1, wall=1.0):
    store = ExperimentStore(str(tmp_path / "exp.sqlite"))
    for i in range(n_runs):
        rid = store.begin_run(engine_rev="models4", backend="cpu",
                              mode="test", sha=f"sha{i}")
        store.record_cell(
            rid, "engine", "batch_warm", statics_key="abc123",
            wall_cold_s=9.0, warm_walls=[wall + 0.01 * i, wall + 0.02],
            lane_params={"rounds": 4},
            metrics={"auc_mean": (0.9, 1), "ratio": (1.1, -1),
                     "info": 42.0})
    return store


def test_store_round_trip_and_history(tmp_path):
    store = _tiny_store(tmp_path, n_runs=3)
    assert store.run_ids() == [1, 2, 3]
    assert store.latest_run_id() == 3
    (cell,) = store.cells_of_run(3)
    assert cell["bench"] == "engine" and cell["lane_key"] == "batch_warm"
    assert cell["engine_rev"] == "models4" and cell["git_sha"] == "sha2"
    assert cell["wall_warm_s"] == min(cell["warm_walls"])
    assert cell["lane_params"] == {"rounds": 4}
    assert cell["metrics"]["auc_mean"] == {"value": 0.9, "direction": 1}
    assert cell["metrics"]["info"]["direction"] == 0

    hist = store.history("engine", "batch_warm", engine_rev="models4",
                         statics_key="abc123", before_run=3)
    assert [c["run_id"] for c in hist] == [1, 2]
    assert store.history("engine", "batch_warm",
                         statics_key="other") == []
    traj = store.metric_history("engine", "batch_warm", "auc_mean")
    assert traj == [(1, 0.9), (2, 0.9), (3, 0.9)]
    assert store.lanes("engine") == [("engine", "batch_warm")]
    assert store.query_plan_uses_index()
    store.close()


def test_metric_trajectory_crosses_engine_revs(tmp_path):
    # metric_history is per-rev by design; the trajectory report is the
    # complementary cross-rev view, each point labelled with its rev.
    store = ExperimentStore(str(tmp_path / "exp.sqlite"))
    for rev, auc in [("models3", 0.58), ("models4", 0.61),
                     ("models4", 0.63)]:
        rid = store.begin_run(engine_rev=rev, backend="cpu", mode="test")
        store.record_cell(rid, "hillclimb", "A:baseline",
                          metrics={"roofline_s": (auc, -1)})
    traj = store.metric_trajectory("hillclimb", "A:baseline", "roofline_s")
    assert traj == [(1, "models3", 0.58), (2, "models4", 0.61),
                    (3, "models4", 0.63)]
    report = store.trajectory_report("hillclimb", "roofline_s")
    assert "A:baseline" in report and "models3" in report
    assert "no stored cells" in store.trajectory_report("hillclimb",
                                                        "nope")
    assert store.metric_trajectory("hillclimb", "A:baseline", "nope") == []
    store.close()


# ---------------------------------------------------------------------------
# regression gate: idle without history, fires on injection, quiet on replay
# ---------------------------------------------------------------------------

BASE_WALLS = [1.00, 1.03, 0.98]          # jittered — ties break Mann-Whitney


def _cell(walls, auc=0.90, run_id=9):
    return {"bench": "engine", "lane_key": "batch_warm", "run_id": run_id,
            "warm_walls": list(walls),
            "metrics": {"auc_mean": {"value": auc, "direction": 1}}}


def _history(n_runs=3):
    return [_cell([w + 0.005 * i for w in BASE_WALLS], run_id=i + 1)
            for i in range(n_runs)]


def test_gate_idle_on_insufficient_history():
    _, regressions = bench_regress.check_cell(
        _cell([3.0, 3.1, 3.2]), _history(1))
    assert regressions == []              # 1 run < min_history_runs=2


def test_gate_fires_on_injected_wall_regression():
    _, regressions = bench_regress.check_cell(
        _cell([3.0, 3.1, 2.9]), _history(3))
    assert len(regressions) == 1 and "warm wall" in regressions[0]


def test_gate_quiet_on_replay():
    _, regressions = bench_regress.check_cell(
        _cell([1.01, 0.99, 1.02]), _history(3))
    assert regressions == []


def test_gate_needs_both_significance_and_ratio():
    # consistently 1% slower: MW may flag it, but the 1.25x ratio guard
    # keeps one-percent drift out of the failure set
    _, regressions = bench_regress.check_cell(
        _cell([w * 1.01 for w in BASE_WALLS]), _history(3))
    assert regressions == []


def test_gate_fires_on_gated_metric_drop():
    _, regressions = bench_regress.check_cell(
        _cell([1.0, 1.01, 0.99], auc=0.70), _history(3))
    assert len(regressions) == 1 and "auc_mean" in regressions[0]


def test_check_store_end_to_end(tmp_path):
    store = _tiny_store(tmp_path, n_runs=3)
    # replay run: same walls -> quiet
    _, regressions = bench_regress.check_store(store)
    assert regressions == []
    # injected run: 3x walls + auc collapse -> both gates fire
    rid = store.begin_run(engine_rev="models4", mode="test", sha="bad")
    store.record_cell(rid, "engine", "batch_warm", statics_key="abc123",
                      warm_walls=[3.0, 3.05, 2.95],
                      metrics={"auc_mean": (0.5, 1), "ratio": (1.1, -1)})
    verdicts, regressions = bench_regress.check_store(store)
    assert len(regressions) == 2
    assert any("warm wall" in r for r in regressions)
    assert any("auc_mean" in r for r in regressions)
    store.close()


# ---------------------------------------------------------------------------
# orchestrator gate map + schema checker against the repo's real artifacts
# ---------------------------------------------------------------------------

def test_run_py_gate_checks():
    from benchmarks import run as run_mod

    assert run_mod.check_gates(
        "engine", {"acceptance": {"pass_under_2x": False}})
    assert not run_mod.check_gates(        # un-gated smoke verdict
        "sweep", {"acceptance": {"pass_warm_not_slower": False,
                                 "gated": False}})
    assert not run_mod.check_gates("engine", None)
    names = run_mod.discover()
    assert {"engine", "sweep", "privacy", "fault", "models", "serve",
            "scale"} <= set(names)


def test_bench_schema_checker_on_real_artifacts():
    present = [b for b in check_bench_schema.SCHEMAS
               if (ROOT / b).exists()]
    if not present:
        pytest.skip("no BENCH_*.json artifacts in this checkout")
    r = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_bench_schema.py"),
         "--root", str(ROOT)],
        capture_output=True, text=True)
    assert r.returncode == 0, f"\n{r.stdout}{r.stderr}"


def test_bench_schema_checker_flags_corruption(tmp_path):
    bad = {"mode": "full"}                 # everything else missing
    p = tmp_path / "BENCH_engine.json"
    p.write_text(json.dumps(bad))
    errs = check_bench_schema.check_file(
        str(p), check_bench_schema.SCHEMAS["BENCH_engine.json"])
    assert errs
