#!/usr/bin/env python
"""Schema-validate the eight legacy ``BENCH_*.json`` artifacts.

The JSON snapshots are the benches' compatibility surface: docs cite their
numbers and tools/bench_regress.py's legacy import path reads their gate
fields.  A refactor that silently drops a gate flag (``gated``,
``pass_under_2x``, ``runner_compiles``...) would leave a stale artifact
that still LOOKS healthy.  This checker pins, per bench, the dotted paths
that must exist and their types — run in tier-1 CI.

Schema language: ``{"dotted.path": type_spec}`` where a ``[]`` segment
means "every element of this list".  ``type_spec`` is a Python type, a
tuple of types, or the string "number" (int or float — JSON does not
distinguish).  Missing path or wrong type → failure.

Usage: python tools/check_bench_schema.py [--root DIR]
Exit 0 when every present artifact validates; a missing file is reported
but only fails with --require-all (artifacts are build products, not
source).  Exit 1 on any validation failure.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

NUM = "number"

SCHEMAS = {
    "BENCH_engine.json": {
        "config.n_clients": int,
        "config.rounds": int,
        "config.seeds[]": int,
        "legacy_single.wall_s": NUM,
        "batch.wall_s_cold": NUM,
        "batch.execute_s_min_of_3": NUM,
        "batch.execute_s_all[]": NUM,
        "batch.wall_s_warm": NUM,
        "speedup.warm_batch_vs_legacy_per_seed_round": NUM,
        "acceptance.ratio": NUM,
        "acceptance.pass_under_2x": bool,
        "equivalence.acc_abs_diff": NUM,
        "equivalence.eps_abs_diff": NUM,
    },
    "BENCH_sweep.json": {
        "mode": str,
        "config.n_lanes": int,
        "percell.wall_s_cold": NUM,
        "percell_shared.execute_s_min_of_3": NUM,
        "sweep.execute_s_min_of_3": NUM,
        "sweep.execute_s_all[]": NUM,
        "sweep.runner_compiles": int,
        "equivalence.max_abs_acc_diff": NUM,
        "equivalence.eps_exact": bool,
        "acceptance.ratio": NUM,
        "acceptance.pass_warm_not_slower": bool,
        "acceptance.gated": bool,
    },
    "BENCH_models.json": {
        "mode": str,
        "config.warm_n": int,
        "grid[].dataset": str,
        "grid[].model": str,
        "grid[].auc_mean": NUM,
        "grid[].warm_execute_s_min": NUM,
        "grid[].warm_execute_s_all[]": NUM,
        "grid[].runner_compiles": int,
        "road_raw_auc.window_native_matches_or_beats_mlp": bool,
        "road_raw_auc.cnn": NUM,
        "road_raw_auc.best_sequence": NUM,
        "road_raw_auc.best_sequence_model": str,
        "road_raw_auc.sequence_beats_cnn": bool,
        "road_raw_auc.gated": bool,
    },
    "BENCH_privacy.json": {
        "mode": str,
        "config.budgets[]": NUM,
        "frontier.runner_compiles": int,
        "frontier.cells[].budget": NUM,
        "frontier.cells[].auc_mean": NUM,
        "frontier.cells[].eps_spent_mean": NUM,
        "overhead.baseline_execute_s_min": NUM,
        "overhead.scheduled_execute_s_min": NUM,
        "overhead.ratio": NUM,
        "overhead.pass_within_5pct": bool,
        "overhead.gated": bool,
        "offline_check.rel_err": NUM,
    },
    "BENCH_fault.json": {
        "mode": str,
        "config.n_lanes": int,
        "frontier.warm_execute_s_min": NUM,
        "frontier.warm_execute_s_all[]": NUM,
        "frontier.runner_compiles": int,
        "frontier.cells[].process": str,
        "frontier.cells[].rate": NUM,
        "frontier.cells[].auc_mean": NUM,
        "coupling_gate.mannwhitney_u": NUM,
        "coupling_gate.p_value": NUM,
        "coupling_gate.gated": bool,
        "ft_ablation.p_value": NUM,
        "ft_ablation.gated": bool,
    },
    "BENCH_async.json": {
        "mode": str,
        "config.n_lanes": int,
        "frontier.warm_execute_s_min": NUM,
        "frontier.warm_execute_s_all[]": NUM,
        "frontier.runner_compiles": int,
        "frontier.cells[].plan": str,
        "frontier.cells[].fault": str,
        "frontier.cells[].auc_mean": NUM,
        "frontier.cells[].sim_time_mean": NUM,
        "async_gate.mannwhitney_u": NUM,
        "async_gate.p_value_time": NUM,
        "async_gate.async_beats_sync": bool,
        "async_gate.gated": bool,
    },
    "BENCH_scale.json": {
        "engine_rev": str,
        "smoke": bool,
        "rounds": int,
        "k_max": int,
        "populations[].n_clients": int,
        "populations[].cold_s": NUM,
        "populations[].warm_s": NUM,
        "populations[].warm_walls_s[]": NUM,
        "runner_stats.misses": int,
        "sublinear.pop_ratio": NUM,
        "sublinear.wall_ratio": NUM,
        "sublinear.ok": bool,
        "memory.n_clients": int,
    },
    "BENCH_serve.json": {
        "mode": str,
        "config.warm_n": int,
        "grid[].dataset": str,
        "grid[].model": str,
        "grid[].bucket": int,
        "grid[].windows_per_sec": NUM,
        "grid[].p50_ms": NUM,
        "grid[].p99_ms": NUM,
        "grid[].scorer_compiles": int,
        "naive_baseline[].speedup_vs_naive": NUM,
        "naive_baseline[].gate_5x": bool,
        "gate.required_speedup": NUM,
        "gate.all_models_pass": bool,
        "gate.gated": bool,
    },
}


def _type_ok(value, spec) -> bool:
    if spec is NUM:
        return isinstance(value, (int, float)) and not isinstance(value, bool)
    if spec is bool:
        return isinstance(value, bool)
    if spec is int:
        return isinstance(value, int) and not isinstance(value, bool)
    return isinstance(value, spec)


def _check_path(obj, segs, spec, where, errors):
    if not segs:
        if not _type_ok(obj, spec):
            want = spec if isinstance(spec, str) else spec.__name__
            errors.append(f"{where}: expected {want}, "
                          f"got {type(obj).__name__} ({obj!r})")
        return
    seg, rest = segs[0], segs[1:]
    if seg.endswith("[]"):
        key = seg[:-2]
        if key:
            if not isinstance(obj, dict) or key not in obj:
                errors.append(f"{where}.{key}: missing")
                return
            obj = obj[key]
            where = f"{where}.{key}"
        if not isinstance(obj, list):
            errors.append(f"{where}: expected list, got {type(obj).__name__}")
            return
        if not obj:
            errors.append(f"{where}: empty list")
            return
        for i, item in enumerate(obj):
            _check_path(item, rest, spec, f"{where}[{i}]", errors)
        return
    if not isinstance(obj, dict) or seg not in obj:
        errors.append(f"{where}.{seg}: missing")
        return
    _check_path(obj[seg], rest, spec, f"{where}.{seg}", errors)


def check_file(path: str, schema: dict) -> list:
    """Validate one artifact; returns a list of error strings."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        return [f"{os.path.basename(path)}: unreadable ({e})"]
    errors = []
    name = os.path.basename(path)
    for dotted, spec in schema.items():
        segs = []
        for part in dotted.split("."):
            segs.append(part)
        _check_path(doc, segs, spec, name, errors)
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", default=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))),
        help="directory holding the BENCH_*.json files (default: repo root)")
    ap.add_argument("--require-all", action="store_true",
                    help="fail when an expected artifact file is absent")
    args = ap.parse_args(argv)

    failures, checked, missing = [], 0, []
    for fname, schema in sorted(SCHEMAS.items()):
        path = os.path.join(args.root, fname)
        if not os.path.exists(path):
            missing.append(fname)
            continue
        errs = check_file(path, schema)
        checked += 1
        if errs:
            failures.extend(errs)
            print(f"FAIL {fname}: {len(errs)} problem(s)")
            for e in errs:
                print(f"  - {e}")
        else:
            print(f"ok   {fname} ({len(schema)} paths)")
    for fname in missing:
        print(f"skip {fname} (absent)")
    if missing and args.require_all:
        failures.extend(f"{m}: missing" for m in missing)
    print(f"checked {checked}/{len(SCHEMAS)} artifacts, "
          f"{len(failures)} failure(s)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
