#!/usr/bin/env python
"""CI regression gate over the experiment store (docs/DESIGN.md §8).

Compares the LATEST stored run's cells against that lane's history in the
store (same bench, lane_key, ENGINE_REV, statics_key — the indexed lookup)
and fails CI when:

* **warm wall regressed** — the current cell's min-of-N warm walls are
  stochastically greater than the pooled history walls by a one-sided
  Mann-Whitney U at ``--alpha`` (src/repro/stats.py, the same helper the
  paper-metric gates use), AND the min-of-N ratio exceeds
  ``--wall-ratio`` (both tests must agree: MW alone would flag a
  consistent +1 % drift, the ratio alone would flag one noisy run);
* **gated metric regressed** — a metric stored with direction ``+1``
  (higher-better, e.g. AUC) fell below, or ``-1`` (lower-better) rose
  above, the history median by more than ``--metric-rtol`` relative.

Statistics of "needs ≥2 stored runs before it can fail": with N warm
walls per cell the one-sided exact MW minimum p is ``1/C(2N, N)`` — for
the common N=3 that is 1/20 = 0.05, which is NOT < 0.05, so a single
history run can never fire at the default alpha.  Two pooled history
runs (≥6 samples vs 3) give min p = 1/84.  ``--min-history-runs``
(default 2) makes that guard explicit: lanes with thinner history report
``insufficient history`` and pass.

Usage:
  python tools/bench_regress.py [--store PATH] [--run-id N]
      [--alpha 0.05] [--wall-ratio 1.25] [--metric-rtol 0.05]
      [--min-history-runs 2] [--bench NAME]

Exit codes: 0 = no regression (incl. empty store / insufficient
history), 1 = regression detected, 2 = usage error.
"""
from __future__ import annotations

import argparse
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro.obs.store import ExperimentStore, default_store_path  # noqa: E402
from repro.stats import mannwhitney_greater  # noqa: E402


def _median(xs):
    s = sorted(xs)
    n = len(s)
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


def check_cell(cell, history, *, alpha=0.05, wall_ratio=1.25,
               metric_rtol=0.05, min_history_runs=2):
    """Gate one current cell against its history cells.

    Returns ``(verdicts, regressions)`` — ``verdicts`` is a list of
    human-readable lines, ``regressions`` the subset that fail the gate.
    """
    verdicts, regressions = [], []
    lane = f"{cell['bench']}/{cell['lane_key']}"
    hist_runs = sorted({c["run_id"] for c in history})
    if len(hist_runs) < min_history_runs:
        verdicts.append(
            f"PASS {lane}: insufficient history "
            f"({len(hist_runs)} run(s) < {min_history_runs}) — gate idle")
        return verdicts, regressions

    # -- warm wall ---------------------------------------------------------
    cur_walls = cell.get("warm_walls") or []
    hist_walls = [w for c in history for w in (c.get("warm_walls") or [])]
    if cur_walls and hist_walls:
        ratio = min(cur_walls) / min(hist_walls)
        u, p, sig = mannwhitney_greater(cur_walls, hist_walls, alpha=alpha)
        if sig and ratio > wall_ratio:
            line = (f"FAIL {lane}: warm wall regressed — min-of-N ratio "
                    f"{ratio:.2f}x (> {wall_ratio}), MW U={u:.1f} "
                    f"p={p:.4f} (< {alpha})")
            regressions.append(line)
            verdicts.append(line)
        else:
            verdicts.append(
                f"PASS {lane}: warm wall ok (ratio {ratio:.2f}x, "
                f"MW p={p:.4f}, n={len(cur_walls)} vs "
                f"{len(hist_walls)} pooled)")

    # -- gated metrics -----------------------------------------------------
    for name, m in sorted((cell.get("metrics") or {}).items()):
        direction = m.get("direction", 0)
        if direction == 0 or m.get("value") is None:
            continue
        hist_vals = []
        for c in history:
            hm = (c.get("metrics") or {}).get(name)
            if hm and hm.get("value") is not None:
                hist_vals.append(hm["value"])
        if not hist_vals:
            verdicts.append(f"PASS {lane}.{name}: no history values")
            continue
        cur, med = m["value"], _median(hist_vals)
        tol = metric_rtol * max(abs(med), 1e-12)
        worse = ((direction > 0 and cur < med - tol)
                 or (direction < 0 and cur > med + tol))
        arrow = "higher-better" if direction > 0 else "lower-better"
        if worse:
            line = (f"FAIL {lane}.{name}: gated metric regressed "
                    f"({arrow}) — {cur:.6g} vs history median {med:.6g} "
                    f"(rtol {metric_rtol})")
            regressions.append(line)
            verdicts.append(line)
        else:
            verdicts.append(
                f"PASS {lane}.{name}: {cur:.6g} vs median {med:.6g} "
                f"({arrow}, rtol {metric_rtol})")
    return verdicts, regressions


def check_store(store, *, run_id=None, bench=None, alpha=0.05,
                wall_ratio=1.25, metric_rtol=0.05, min_history_runs=2):
    """Gate every cell of ``run_id`` (default: latest run) against its
    per-lane history.  Returns ``(verdicts, regressions)``."""
    if run_id is None:
        run_id = store.latest_run_id()
    if run_id is None:
        return (["PASS: store is empty — nothing to gate"], [])
    cells = store.cells_of_run(run_id)
    if bench is not None:
        cells = [c for c in cells if c["bench"] == bench]
    if not cells:
        return ([f"PASS: run {run_id} recorded no matching cells"], [])
    verdicts, regressions = [], []
    for cell in cells:
        history = store.history(
            cell["bench"], cell["lane_key"],
            engine_rev=cell.get("engine_rev"),
            statics_key=cell.get("statics_key"),
            before_run=run_id)
        v, r = check_cell(cell, history, alpha=alpha, wall_ratio=wall_ratio,
                          metric_rtol=metric_rtol,
                          min_history_runs=min_history_runs)
        verdicts.extend(v)
        regressions.extend(r)
    return verdicts, regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--store", default=None,
                    help="store path (default: REPRO_STORE env or "
                         "benchmarks/artifacts/experiments.sqlite)")
    ap.add_argument("--run-id", type=int, default=None,
                    help="run to gate (default: latest)")
    ap.add_argument("--bench", default=None,
                    help="restrict to one bench name")
    ap.add_argument("--alpha", type=float, default=0.05)
    ap.add_argument("--wall-ratio", type=float, default=1.25,
                    help="min-of-N warm-wall ratio that must ALSO be "
                         "exceeded before a wall regression fires")
    ap.add_argument("--metric-rtol", type=float, default=0.05)
    ap.add_argument("--min-history-runs", type=int, default=2,
                    help="history runs required before the gate can fail "
                         "(see module docstring for the MW power argument)")
    args = ap.parse_args(argv)

    path = args.store or default_store_path()
    if not os.path.exists(path):
        print(f"PASS: no store at {path} — nothing to gate")
        return 0
    store = ExperimentStore(path)
    try:
        verdicts, regressions = check_store(
            store, run_id=args.run_id, bench=args.bench, alpha=args.alpha,
            wall_ratio=args.wall_ratio, metric_rtol=args.metric_rtol,
            min_history_runs=args.min_history_runs)
    finally:
        store.close()
    for line in verdicts:
        print(line)
    print(f"{len(regressions)} regression(s) across "
          f"{len(verdicts)} check(s)")
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main())
