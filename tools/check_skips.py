#!/usr/bin/env python
"""Tier-1 skip audit (ISSUE 6): the skip count must never silently grow.

Reads a pytest ``-rs`` log (file argument, or stdin) and enforces two
invariants:

1. **Bounded count** — at most ``MAX_SKIPS`` skipped tests.  The seed
   baseline is 5: four dry-run-artifact guards in tests/test_artifacts.py
   plus the optional-hypothesis module skip in
   tests/test_core_properties.py (absent in CI, where hypothesis is
   installed).  A new skip is a capability statement and must be a
   deliberate decision: add its reason to ``ALLOWED`` *and* bump the
   bound in the same review.
2. **Named capability** — every skip reason must match one of the
   ``ALLOWED`` patterns, each of which names the missing capability
   (artifact set, optional dependency, device count, accelerator).  A
   bare ``pytest.skip("...")`` with an ad-hoc reason fails the audit.

Exit 0 when both hold; prints the offending lines and exits 1 otherwise.
Usage: ``python -m pytest -rs -q | tee log && python tools/check_skips.py log``
"""
from __future__ import annotations

import re
import sys

MAX_SKIPS = 5

# Each pattern names a missing capability a skip may legitimately declare.
ALLOWED = (
    r"dry-run sweep artifacts absent",      # benchmarks/artifacts not built
    r"optional test extra 'hypothesis'",    # optional dependency
    r"could not import 'hypothesis'",       # same, via older importorskip
    r"requires \d+ devices",                # multi-device-only test
    r"requires TPU",                        # accelerator-only test
)

_SKIP_RE = re.compile(r"^SKIPPED \[(\d+)\] (\S+): (.*)$")


def audit(lines) -> int:
    total = 0
    errors = []
    for line in lines:
        m = _SKIP_RE.match(line.strip())
        if not m:
            continue
        count, where, reason = int(m.group(1)), m.group(2), m.group(3)
        total += count
        if not any(re.search(p, reason) for p in ALLOWED):
            errors.append(
                f"  {where}: unrecognised skip reason {reason!r} — name "
                "the missing capability and allow-list it in "
                "tools/check_skips.py")
    if total > MAX_SKIPS:
        errors.append(
            f"  skip count grew: {total} > baseline {MAX_SKIPS} — skips "
            "may only decrease (ISSUE 6); if a new skip is deliberate, "
            "bump MAX_SKIPS in tools/check_skips.py in the same change")
    if errors:
        print(f"skip audit FAILED ({total} skips):")
        print("\n".join(errors))
        return 1
    print(f"skip audit OK: {total} skip(s) <= {MAX_SKIPS}, all reasons "
          "name their missing capability")
    return 0


def main() -> int:
    if len(sys.argv) > 1:
        with open(sys.argv[1]) as f:
            return audit(f)
    return audit(sys.stdin)


if __name__ == "__main__":
    sys.exit(main())
