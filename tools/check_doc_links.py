#!/usr/bin/env python
"""Docs citation checker (ISSUE 5): no dangling doc references, ever again.

The repo's code annotates itself with citations like ``DESIGN.md §4``,
``docs/ARCHITECTURE.md §Privacy`` or ``EXPERIMENTS.md §Sweeps``.  Twelve
call sites cited a DESIGN.md that did not exist for four PRs — this script
makes that class of rot a CI failure:

* every ``<Name>.md`` mentioned in ``src/``, ``tests/``, ``benchmarks/``,
  ``examples/`` must exist at the repo root or under ``docs/``;
* every ``<Name>.md §<section>`` citation into the narrative docs
  (DESIGN / ARCHITECTURE / EXPERIMENTS / README) must resolve to a real
  heading: either a literal ``§<section>`` anchor (EXPERIMENTS.md and
  DESIGN.md number/name their sections that way) or a heading containing
  the section token (ARCHITECTURE.md's prose headings).

Run from anywhere: ``python tools/check_doc_links.py``.  Exit code 0 =
clean; nonzero prints every dangling citation.  Wired into CI (tier1
job) and ``tests/test_docs.py``.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SCAN_DIRS = ("src", "tests", "benchmarks", "examples", "tools")
DOC_LOCATIONS = ("", "docs/")
# files whose §-citations must resolve to a heading
SECTION_CHECKED = {"DESIGN.md", "ARCHITECTURE.md", "EXPERIMENTS.md",
                   "README.md"}

MD_REF = re.compile(r"\b(?:docs/)?([A-Z][A-Za-z0-9_]*\.md)\b")
SEC_REF = re.compile(
    r"\b(?:docs/)?([A-Z][A-Za-z0-9_]*\.md)\s*§\s*([A-Za-z0-9][A-Za-z0-9/_-]*)")


def resolve(name: str) -> Path | None:
    for prefix in DOC_LOCATIONS:
        p = ROOT / prefix / name
        if p.exists():
            return p
    return None


def headings(path: Path) -> list:
    return [ln.strip() for ln in path.read_text().splitlines()
            if ln.lstrip().startswith("#")]


def section_resolves(heads: list, token: str) -> bool:
    """A §token resolves to a literal '§token' heading anchor, or (for
    non-numeric tokens) to any heading containing the token as a
    substring (ARCHITECTURE.md-style prose headings)."""
    anchored = re.compile(r"§\s*" + re.escape(token) + r"(?![A-Za-z0-9])",
                          re.IGNORECASE)
    if any(anchored.search(h) for h in heads):
        return True
    if not token[0].isdigit():
        t = token.lower()
        return any(t in h.lower() for h in heads)
    return False


def check() -> list:
    errors = []
    head_cache = {}
    for d in SCAN_DIRS:
        base = ROOT / d
        if not base.is_dir():
            continue
        for f in sorted(base.rglob("*.py")):
            text = f.read_text()
            rel = f.relative_to(ROOT)
            for m in MD_REF.finditer(text):
                if resolve(m.group(1)) is None:
                    line = text[: m.start()].count("\n") + 1
                    errors.append(f"{rel}:{line}: cites missing doc "
                                  f"{m.group(1)!r}")
            for m in SEC_REF.finditer(text):
                name, token = m.groups()
                if name not in SECTION_CHECKED:
                    continue
                path = resolve(name)
                if path is None:
                    continue  # already reported above
                if path not in head_cache:
                    head_cache[path] = headings(path)
                if not section_resolves(head_cache[path], token):
                    line = text[: m.start()].count("\n") + 1
                    errors.append(f"{rel}:{line}: {name} has no section "
                                  f"matching '§{token}'")
    return errors


def main() -> int:
    errors = check()
    if errors:
        print(f"{len(errors)} dangling doc citation(s):")
        for e in errors:
            print(" ", e)
        return 1
    print("doc citations OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
