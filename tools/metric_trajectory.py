#!/usr/bin/env python
"""Print a metric's trajectory across ENGINE_REV from the experiment store.

The ROADMAP's promised report: the regression gate (tools/bench_regress.py)
compares within one engine revision on purpose, so this is the
complementary view — follow one metric (AUC, warm wall, a hillclimb
roofline term) through engine rewrites, each point labelled with the rev
that produced it.

Usage:
  PYTHONPATH=src python tools/metric_trajectory.py --bench fault \\
      --metric auc_mean [--lane iid@0.30] [--store PATH]

Without --lane, every lane of the bench is reported.  Exit 0 always —
this is a report, not a gate.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.obs.store import ExperimentStore, default_store_path  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bench", required=True)
    ap.add_argument("--metric", default="auc_mean")
    ap.add_argument("--lane", default=None,
                    help="one lane_key (default: every lane of the bench)")
    ap.add_argument("--store", default=None,
                    help="sqlite path (default: REPRO_STORE or "
                         "benchmarks/artifacts/experiments.sqlite)")
    args = ap.parse_args()

    path = args.store or default_store_path()
    if not os.path.exists(path):
        print(f"no experiment store at {path} — run a bench first")
        return 0
    store = ExperimentStore(path)
    if args.lane:
        traj = store.metric_trajectory(args.bench, args.lane, args.metric)
        print(f"== {args.bench}/{args.lane}: {args.metric} across "
              "ENGINE_REV ==")
        prev = None
        for run_id, rev, v in traj:
            delta = "" if prev is None else f"  ({v - prev:+.4f})"
            print(f"  run {run_id:>4d} [{rev or '?':>10s}]  {v:.4f}{delta}")
            prev = v
        if not traj:
            print(f"  (no stored cells carry metric {args.metric!r})")
    else:
        print(store.trajectory_report(args.bench, args.metric))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
