"""Pytree checkpointing (binary, dependency-free) + Weibull-driven cadence.

The paper's fault-tolerance mechanism stores client model state as binary
files at interval t_c* (derived in ``core/fault.py``).  This module is the
substrate: flatten a pytree to a single ``.npz`` with '/'-joined key paths,
a JSON manifest, atomic rename, and restore-latest with integrity checks.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_pytree(path: str, tree, metadata: Optional[dict] = None) -> str:
    """Atomic save of a pytree to <path>.npz (+ sidecar manifest)."""
    flat = _flatten(tree)
    os.makedirs(os.path.dirname(os.path.abspath(path)) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(suffix=".npz", dir=os.path.dirname(os.path.abspath(path)))
    os.close(fd)
    np.savez(tmp, **flat)
    final = path if path.endswith(".npz") else path + ".npz"
    shutil.move(tmp, final)
    manifest = {
        "keys": sorted(flat),
        "time": time.time(),
        "nbytes": int(sum(v.nbytes for v in flat.values())),
        "metadata": metadata or {},
    }
    with open(final + ".json", "w") as f:
        json.dump(manifest, f)
    return final


def load_manifest(path: str) -> dict:
    """The sidecar manifest ``save_pytree`` wrote next to the ``.npz`` —
    keys, byte count and the caller's ``metadata`` dict.  The serving
    engine (``repro/serve``) stores the model name and dataset metadata
    there so a checkpoint is self-describing: ``ServeEngine.from_checkpoint``
    rebuilds the ModelSpec and the restore template from the manifest alone.
    """
    final = path if path.endswith(".npz") else path + ".npz"
    with open(final + ".json") as f:
        return json.load(f)


def load_flat(path: str) -> Dict[str, np.ndarray]:
    final = path if path.endswith(".npz") else path + ".npz"
    with np.load(final) as z:
        return {k: z[k] for k in z.files}


def restore_pytree(path: str, like) -> Any:
    """Restore into the structure of ``like`` (shape/dtype checked)."""
    flat = load_flat(path)
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in paths_leaves:
        key = "/".join(_path_str(x) for x in p)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        if hasattr(leaf, "dtype"):
            want = np.dtype(leaf.dtype)
            if arr.dtype.kind == "V":  # npz stores ml_dtypes (bf16, ...) as raw void
                arr = arr.view(want)
            else:
                arr = arr.astype(want)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class Checkpointer:
    """Rotating checkpoint directory with restore-latest.

    ``interval_rounds`` usually comes from
    ``core.fault.optimal_checkpoint_interval`` divided by the measured
    per-round wall time (the driver wires that up).
    """

    def __init__(self, directory: str, keep: int = 3, interval_rounds: int = 1):
        self.dir = directory
        self.keep = keep
        self.interval = max(int(interval_rounds), 1)
        self.saves = 0
        os.makedirs(directory, exist_ok=True)

    def maybe_save(self, round_idx: int, tree, metadata=None) -> Optional[str]:
        if round_idx % self.interval:
            return None
        path = os.path.join(self.dir, f"ckpt_{round_idx:08d}")
        out = save_pytree(path, tree, {"round": round_idx, **(metadata or {})})
        self.saves += 1
        self._gc()
        return out

    def _gc(self):
        ckpts = sorted(self._list())
        for r, p in ckpts[: -self.keep]:
            for ext in ("", ".json"):
                try:
                    os.remove(p + ext)
                except OSError:
                    pass

    def _list(self):
        out = []
        for f in os.listdir(self.dir):
            if f.startswith("ckpt_") and f.endswith(".npz"):
                out.append((int(f[5:13]), os.path.join(self.dir, f)))
        return out

    def latest(self) -> Optional[Tuple[int, str]]:
        ckpts = sorted(self._list())
        return ckpts[-1] if ckpts else None

    def restore_latest(self, like):
        latest = self.latest()
        if latest is None:
            return None, None
        return latest[0], restore_pytree(latest[1], like)
