"""Mamba2-130m (SSD — state-space duality, arXiv:2405.21060).

24 layers, d_model 768, attention-free, vocab 50280, ssm_state 128,
head_dim 64 (expand 2 → 1536 inner → 24 SSD heads).  Natively O(1)-state:
all decode shapes including ``long_500k`` run in the recurrent form.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m",
        family="ssm",
        n_layers=24,
        d_model=768,
        n_heads=1,       # unused (attention-free)
        n_kv_heads=1,    # unused
        head_dim=64,
        d_ff=0,          # no MLP in the Mamba2 stack
        vocab_size=50280,
        ssm_state=128,
        ssm_head_dim=64,
        ssm_chunk=128,
        ssm_expand=2,
        conv_width=4,
        tie_embeddings=True,
        source="arXiv:2405.21060 (Mamba2 SSD); hf:state-spaces/mamba2-130m",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke",
        family="ssm",
        n_layers=2,
        d_model=128,
        n_heads=1,
        n_kv_heads=1,
        head_dim=32,
        d_ff=0,
        vocab_size=512,
        ssm_state=32,
        ssm_head_dim=32,
        ssm_chunk=16,
        ssm_expand=2,
        conv_width=4,
        tie_embeddings=True,
        source="reduced variant of mamba2-130m",
    )
