"""Mistral-Large-Instruct-2407 (123B dense).

[hf:mistralai/Mistral-Large-Instruct-2407] — 88 layers, d_model 12288,
96 q heads / 8 kv heads (GQA), head_dim 128, d_ff 28672, vocab 32768.
The largest *dense* assigned model — the client_serial FL plan is mandatory
(DESIGN.md §4).  ``long_500k`` runs the labeled sliding-window variant.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b",
        family="dense",
        n_layers=88,
        d_model=12288,
        n_heads=96,
        n_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab_size=32768,
        act="swiglu",
        rope_theta=1_000_000.0,
        long_context_variant="swa-4096",
        source="hf:mistralai/Mistral-Large-Instruct-2407",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=8,
        n_kv_heads=2,
        head_dim=16,
        d_ff=256,
        vocab_size=512,
        act="swiglu",
        long_context_variant="swa-64",
        source="reduced variant of mistral-large-123b",
    )
