"""SeamlessM4T-large-v2 transformer backbone (arXiv:2308.11596).

Encoder-decoder: the assigned "24L" is read as 24 encoder + 24 decoder
layers per the model card (DESIGN.md §5), d_model 1024, 16 heads (kv=16 —
full MHA), d_ff 8192, vocab 256206.  The audio frontend (mel-spectrogram +
conv feature extractor / w2v-BERT) is a STUB per the assignment carve-out:
``input_specs`` feeds precomputed frame embeddings [B, enc_seq, d].
``long_500k`` on the decoder runs the labeled sliding-window variant.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2",
        family="encdec",
        n_layers=24,       # decoder layers
        enc_layers=24,     # encoder layers
        enc_seq=1024,      # stub-frontend frame embeddings fed to the encoder
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        head_dim=64,
        d_ff=8192,
        vocab_size=256_206,
        frontend="audio",
        act="gelu",
        long_context_variant="swa-4096",
        source="arXiv:2308.11596 (SeamlessM4T v2)",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-smoke",
        family="encdec",
        n_layers=2,
        enc_layers=2,
        enc_seq=16,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        frontend="audio",
        act="gelu",
        long_context_variant="swa-32",
        source="reduced variant of seamless-m4t-large-v2",
    )
