"""Llama-4 Maverick (400B total / 17B active).

[hf:meta-llama/Llama-4-Scout-17B-16E family card] — 48 layers, d_model 5120,
40 q heads / 8 kv heads (GQA), d_ff 8192 per expert, 128 experts top-1,
vocab 202048.  "Early fusion" multimodality enters as precomputed embeddings
through the ``frontend`` hook (stubbed per the assignment carve-out); the
assigned family is [moe], so the default configuration is text-only.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202048,
        n_experts=128,
        experts_per_token=1,
        capacity_factor=1.25,
        # interleaved dense/MoE layers (the published 400B total only adds up
        # with every other layer MoE; all-MoE would be ~778B)
        block_pattern=("attn", "moe"),
        act="swiglu",
        rope_theta=500_000.0,
        long_context_variant="swa-4096",
        source="hf:meta-llama/Llama-4-Scout-17B-16E (Maverick sibling)",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-smoke",
        family="moe",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        n_experts=4,
        experts_per_token=1,
        capacity_factor=1.25,
        block_pattern=("attn", "moe"),
        act="swiglu",
        long_context_variant="swa-64",
        source="reduced variant of llama4-maverick-400b-a17b",
    )
