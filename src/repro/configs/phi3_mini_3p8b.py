"""Phi-3-mini (3.8B dense; arXiv:2404.14219).

32 layers, d_model 3072, 32 q heads / 32 kv heads (full MHA per the
assignment spec), head_dim 96, d_ff 8192, vocab 32064, RoPE + SwiGLU.
``long_500k`` runs the labeled sliding-window variant.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b",
        family="dense",
        n_layers=32,
        d_model=3072,
        n_heads=32,
        n_kv_heads=32,
        head_dim=96,
        d_ff=8192,
        vocab_size=32064,
        act="swiglu",
        rope_theta=10_000.0,
        long_context_variant="swa-4096",
        source="arXiv:2404.14219 (Phi-3)",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        act="swiglu",
        long_context_variant="swa-64",
        source="reduced variant of phi3-mini-3.8b",
    )
