"""The paper's own detector model + FL hyper-parameters (Section V-A).

A tabular feed-forward anomaly detector (per the paper's ref [1]) trained
with 40 clients, 200 communication rounds × 5 local epochs, ε ∈ [0.1, 10],
grid-searched checkpoint interval and client fraction K.
"""
from dataclasses import dataclass

from repro.configs.base import FLConfig


@dataclass(frozen=True)
class PaperMLPConfig:
    name: str = "paper-mlp"
    hidden: int = 128
    n_classes: int = 2


def config() -> PaperMLPConfig:
    return PaperMLPConfig()


def smoke_config() -> PaperMLPConfig:
    return PaperMLPConfig(name="paper-mlp-smoke", hidden=32)


def paper_fl_config(n_clients: int = 40, rounds: int = 200) -> FLConfig:
    """The experimental FL setup of Section V-A."""
    return FLConfig(
        n_clients=n_clients,
        clients_per_round=8,
        adaptive_k=True,
        rounds=rounds,
        local_epochs=5,
        local_batch=64,
        local_lr=0.05,
        selection="adaptive_utility",
        dp_enabled=True,
        dp_epsilon=8.0,
        dp_delta=1e-5,
        dp_clip=1.0,
        fault_tolerance=True,
        failure_prob=0.05,
    )
