"""Phi-3.5-MoE-instruct (42B total / 6.6B active).

[hf:microsoft/Phi-3.5-MoE-instruct] — 32 layers, d_model 4096, 32 q heads /
8 kv heads (GQA), d_ff 6400 per expert, 16 experts top-2, vocab 32064.
``long_500k`` runs only under the labeled sliding-window variant (full
attention cannot hold a 512k cache) — DESIGN.md §5.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b",
        family="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=6400,
        vocab_size=32064,
        n_experts=16,
        experts_per_token=2,
        capacity_factor=1.25,
        act="swiglu",
        rope_theta=10_000.0,
        long_context_variant="swa-4096",
        source="hf:microsoft/Phi-3.5-MoE-instruct",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-smoke",
        family="moe",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        n_experts=4,
        experts_per_token=2,
        capacity_factor=1.25,
        act="swiglu",
        long_context_variant="swa-64",
        source="reduced variant of hf:microsoft/Phi-3.5-MoE-instruct",
    )
