"""RecurrentGemma-9B (Griffin architecture, arXiv:2402.19427).

38 layers in the 2:1 Griffin pattern (rec, rec, local-attn), d_model 4096,
16 q heads / 1 kv head (MQA) with head_dim 256, d_ff 12288, vocab 256000,
RG-LRU width 4096, local attention window 2048.  Natively sub-quadratic:
``long_500k`` runs without any variant (O(1) recurrent state + 2048-window
rolling KV cache).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab_size=256_000,
        block_pattern=("rec", "rec", "attn"),
        lru_width=4096,
        sliding_window=2048,
        conv_width=4,
        act="geglu",
        tie_embeddings=True,
        source="arXiv:2402.19427 (RecurrentGemma); Griffin 2:1 pattern",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-smoke",
        family="hybrid",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=1,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        block_pattern=("rec", "attn"),
        lru_width=128,
        sliding_window=32,
        conv_width=4,
        act="geglu",
        tie_embeddings=True,
        source="reduced variant of recurrentgemma-9b",
    )
