"""Granite-3.0-8B (dense, GQA).

[hf:ibm-granite/granite-3.0-2b-base family card; 8B dims] — 40 layers,
d_model 4096, 32 q heads / 8 kv heads, head_dim 128, d_ff 12800,
vocab 49155, tied embeddings.  ``long_500k`` runs the labeled
sliding-window variant.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b",
        family="dense",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=12800,
        vocab_size=49155,
        act="swiglu",
        tie_embeddings=True,
        rope_theta=10_000.0,
        long_context_variant="swa-4096",
        source="hf:ibm-granite/granite-3.0-2b-base (family card); 8B dims",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        act="swiglu",
        tie_embeddings=True,
        long_context_variant="swa-64",
        source="reduced variant of granite-3-8b",
    )
