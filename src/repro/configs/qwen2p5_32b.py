"""Qwen2.5-32B (dense, GQA, QKV bias).

[hf:Qwen/Qwen2.5-32B; family card hf:Qwen/Qwen2.5-0.5B] — 64 layers,
d_model 5120, 40 q heads / 8 kv heads, head_dim 128, d_ff 27648,
vocab 152064.  ``long_500k`` runs the labeled sliding-window variant.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b",
        family="dense",
        n_layers=64,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=27648,
        vocab_size=152064,
        qkv_bias=True,
        act="swiglu",
        rope_theta=1_000_000.0,
        long_context_variant="swa-4096",
        source="hf:Qwen/Qwen2.5-0.5B (family card); 32B dims",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        qkv_bias=True,
        act="swiglu",
        long_context_variant="swa-64",
        source="reduced variant of qwen2.5-32b",
    )
