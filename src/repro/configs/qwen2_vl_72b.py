"""Qwen2-VL-72B language backbone (arXiv:2409.12191).

80 layers, d_model 8192, 64 q heads / 8 kv heads (GQA, QKV bias), head_dim
128, d_ff 29568, vocab 152064.  M-RoPE with (t, h, w) frequency sections
(16, 24, 24) over head_dim/2 = 64.  The ViT vision encoder + projector is a
STUB per the assignment carve-out: ``input_specs`` feeds 1024 precomputed
patch embeddings (dynamic-resolution stand-in) that are prepended to the
text tokens.  ``long_500k`` runs the labeled sliding-window variant.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b",
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=29568,
        vocab_size=152064,
        qkv_bias=True,
        mrope_sections=(16, 24, 24),
        frontend="vision",
        frontend_tokens=1024,
        act="swiglu",
        rope_theta=1_000_000.0,
        long_context_variant="swa-4096",
        source="arXiv:2409.12191 (Qwen2-VL); hf:Qwen/Qwen2-VL-72B-Instruct",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-smoke",
        family="vlm",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        qkv_bias=True,
        mrope_sections=(4, 6, 6),
        frontend="vision",
        frontend_tokens=16,
        act="swiglu",
        long_context_variant="swa-64",
        source="reduced variant of qwen2-vl-72b",
    )
