"""Configuration system for the repro framework.

Every assigned architecture gets a module in ``repro/configs/<id>.py`` that
exposes ``config() -> ModelConfig`` (the exact published spec) and
``smoke_config() -> ModelConfig`` (a reduced variant of the same family used
by CPU smoke tests: <=2 layers, d_model <= 512, <= 4 experts).

Input shapes, mesh descriptions and FL hyper-parameters live here too so the
launcher, the dry-run and the benchmarks all read one source of truth.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import NamedTuple, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description covering every assigned family."""

    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # --- attention ---
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None  # None = full causal attention
    mrope_sections: Optional[Tuple[int, int, int]] = None  # VLM M-RoPE (t,h,w)

    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    ssm_expand: int = 2
    conv_width: int = 4

    # --- hybrid (RecurrentGemma / Griffin) ---
    # pattern of block kinds, tiled (with truncation) to n_layers,
    # e.g. ("rec", "rec", "attn").
    block_pattern: Optional[Tuple[str, ...]] = None
    lru_width: int = 0  # RG-LRU recurrent width (0 -> d_model)

    # --- encoder-decoder (audio) ---
    enc_layers: int = 0  # 0 => decoder-only
    enc_seq: int = 1024  # stub frontend: number of frame embeddings

    # --- multimodal frontend stubs ---
    frontend: str = "none"  # none | vision | audio
    frontend_tokens: int = 0  # patch/frame embeddings prepended to the prompt

    # --- misc ---
    act: str = "swiglu"  # swiglu | geglu | gelu
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    long_context_variant: Optional[str] = None  # e.g. "swa-4096" for long_500k
    source: str = ""  # citation for the spec

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def pattern(self) -> Tuple[str, ...]:
        """Per-layer block kinds of length n_layers.

        ``block_pattern`` takes priority (e.g. Llama-4's interleaved
        dense/MoE), then family defaults.
        """
        if self.block_pattern:
            reps = -(-self.n_layers // len(self.block_pattern))
            return (self.block_pattern * reps)[: self.n_layers]
        if self.family == "ssm":
            return ("ssd",) * self.n_layers
        if self.family == "moe":
            return ("moe",) * self.n_layers
        return ("attn",) * self.n_layers

    def segments(self) -> Tuple[Tuple[Tuple[str, ...], int], ...]:
        """Greedy decomposition of pattern() into (super-block, repeats).

        A super-block is the smallest repeating unit; the trailing remainder
        becomes its own segment.  Used to build per-segment scanned stacks.
        """
        pat = self.pattern()
        if self.block_pattern:
            unit = self.block_pattern
            n_full = self.n_layers // len(unit)
            segs = []
            if n_full:
                segs.append((tuple(unit), n_full))
            rem = self.n_layers - n_full * len(unit)
            if rem:
                segs.append((tuple(pat[-rem:]), 1))
            return tuple(segs)
        return (((pat[0],), self.n_layers),)

    def supports_long_context(self) -> bool:
        if self.family in ("ssm", "hybrid"):
            return True
        return self.long_context_variant is not None

    def param_count(self) -> int:
        """Approximate parameter count (reported, not load-bearing)."""
        d, dff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (self.n_heads * hd) * d
        mlp_mult = 3 if self.act in ("swiglu", "geglu") else 2
        mlp = mlp_mult * d * dff
        per_layer = 0
        for kind in self.pattern():
            if kind == "attn":
                per_layer += attn + mlp
            elif kind == "moe":
                per_layer += attn + self.n_experts * mlp
            elif kind == "ssd":
                din = self.ssm_expand * d
                per_layer += d * (2 * din + 2 * self.ssm_state) + din * d
            elif kind == "rec":
                w = self.lru_width or d
                per_layer += 2 * d * w + w * d + 3 * w + mlp
        emb = v * d * (1 if self.tie_embeddings else 2)
        enc = self.enc_layers * (attn + mlp) if self.enc_layers else 0
        return per_layer + emb + enc

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if self.family != "moe":
            return self.param_count()
        d, dff = self.d_model, self.d_ff
        mlp_mult = 3 if self.act in ("swiglu", "geglu") else 2
        full = self.param_count()
        unused = (self.n_experts - self.experts_per_token) * mlp_mult * d * dff
        n_moe_layers = sum(1 for k in self.pattern() if k == "moe")
        return full - n_moe_layers * unused


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Federated-learning configuration (the paper's knobs)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FLConfig:
    """Hyper-parameters of Algorithm 1 and its substrate.

    Two kinds of fields live here (docs/ARCHITECTURE.md §Static/runtime):

    * STATIC — shapes, the execution plan, strategy names and booleans that
      gate code structure.  Changing one compiles a new XLA program.
    * RUNTIME (``RUNTIME_FIELDS``) — scalar knobs (learning rates, DP
      budget, failure/availability probabilities, selection temperature,
      adaptive-K thresholds).  The engine reads these from an
      :class:`FLParams` pytree argument at run time, so a whole sweep over
      them shares ONE compiled program; :func:`fl_static` canonicalises a
      config to its static part for program-cache keying.
    """

    # detector architecture (STATIC): a name in the models/spec.py registry
    # ("mlp" — the paper's flattened MLP — plus the window-native ROAD
    # detectors "cnn"/"rglru").  Part of the runner-cache statics key, so
    # each architecture compiles once and a model grid shares the sweep
    # machinery like any other static split.
    model: str = "mlp"
    n_clients: int = 40
    clients_per_round: int = 8          # K (initial value when adaptive)
    adaptive_k: bool = True
    k_min: int = 2
    k_max: int = 0                      # 0 -> n_clients
    rounds: int = 200
    local_epochs: int = 5
    local_batch: int = 64
    local_lr: float = 0.05
    selection: str = "adaptive_utility"  # see core/selection.py registry
    # utility score weights: performance, data quality, compute capacity
    alpha: float = 1.0                  # accuracy weight in F(S_t)
    gamma: float = 0.1                  # cost weight in F(S_t)
    utility_ema: float = 0.5
    explore_noise: float = 0.05         # selection temperature (Gumbel scale)
    avail_prob: float = 0.95            # per-client per-round availability
    k_tol: float = 1e-3                 # adaptive-K plateau tolerance
    k_patience: float = 3.0             # adaptive-K plateau patience (rounds)
    # update-coherence scoring (cos(Δ_i, Δ_agg) data-quality observable,
    # DESIGN.md §4).  Costs one extra all-reduce of params-size per client in
    # the client_parallel plan — negligible for the paper's MLP, material for
    # multi-B LMs, so the LM dry-run profile disables it (EXPERIMENTS.md).
    coherence_scoring: bool = True
    # --- differential privacy ---
    dp_enabled: bool = True
    dp_epsilon: float = 8.0
    dp_delta: float = 1e-5
    dp_clip: float = 1.0
    dp_mode: str = "clipped"            # "paper" (fixed sigma, no clip) | "clipped"
    dp_sigma: float = 0.01              # used in "paper" mode
    # scheduled budget accounting (repro/privacy): STATIC gate — when True,
    # the engine carries an RDP accountant + budget scheduler through the
    # round scan, σ becomes a per-round scheduler output, and rounds whose
    # release would overshoot dp_budget are withheld from the global model
    # (budget-exhaustion masking).  The knobs below are RUNTIME lanes.
    dp_scheduled: bool = False
    dp_budget: float = 50.0             # TOTAL (ε, dp_delta) budget for the run
    dp_sched: float = 0.0               # schedule code: 0 uniform | 1 linear | 2 adaptive
    dp_sched_rate: float = 0.3          # linear slope / adaptive spend step
    dp_stall_tol: float = 1e-3          # adaptive: AUC gain that counts as progress
    # --- fault tolerance ---
    # The failure-scenario engine (repro/fault, docs/DESIGN.md §6):
    # fault_tolerance is the STATIC checkpoint-recovery gate; everything
    # else below is a RUNTIME lane, so a whole (process × rate) fault
    # frontier compiles once — fault_process is a schedule-style code like
    # dp_sched (repro.fault.process_code).
    fault_tolerance: bool = True
    failure_prob: float = 0.05          # marginal per-client per-round rate
    fault_process: float = 0.0          # 0 iid | 1 markov | 2 weibull | 3 straggler
    fault_burst: float = 3.0            # markov: expected outage length (rounds)
    straggler_slow: float = 4.0         # straggler: round-time stretch factor
    fault_util_w: float = 0.0           # selection coupling: utility penalty on
                                        # the per-client failure EMA (0 = off,
                                        # keeping default lanes bitwise)
    weibull_scale: float = 600.0        # lambda (seconds; cost model)
    weibull_shape: float = 1.2          # k (cost model AND lifetime process)
    recovery_time: float = 30.0         # t_r (seconds)
    checkpoint_every: int = 0           # rounds; 0 -> derive from Weibull model
    # --- server ---
    server_opt: str = "sgd"             # sgd | fedavgm | fedadam
    server_lr: float = 1.0
    # --- execution plan ---
    # A name in the core/plans.py RoundPlan registry: client_parallel |
    # client_serial | client_cohort | buffered_async | hierarchical.
    # fl_static canonicalises the name to its STATIC program family, so
    # same-family plans (client_parallel / buffered_async / hierarchical)
    # share ONE compiled program and the concrete choice rides the runtime
    # FLParams.plan_code lane — a mixed sync×async×hier sweep compiles once.
    # Unknown names and incompatible plan/feature combinations are rejected
    # at construction time (__post_init__ -> core.plans.validate_plan).
    plan: str = "client_parallel"
    serial_clients_in_step: int = 4     # K folded into one lowered round step
    local_steps_in_step: int = 1        # local SGD steps per client in the step
    # --- buffered_async plan (RUNTIME lanes; inert at 0 on sync lanes) ---
    async_buffer: float = 0.0           # K of K-of-cohort aggregation (>=1 on
                                        # the async plan; 0 = synchronous)
    async_staleness_pow: float = 0.5    # staleness discount (1+s)^-pow; 0 ->
                                        # all weights 1.0, bitwise sync FedAvg
    # --- hierarchical plan ---
    hier_comm_frac: float = 0.3         # RUNTIME: per-hop edge-comm cost as a
                                        # fraction of the flat WAN hop
    hierarchy_edges: int = 4            # STATIC: edge-aggregator count E
                                        # (client i reports to edge i % E)

    def __post_init__(self):
        # Lazy import: core.plans is import-light and configs.base must not
        # depend on core.rounds at module scope.  Runs on every
        # dataclasses.replace too, so sweep cells are validated as built.
        from repro.core.plans import validate_plan
        validate_plan(self)


class FLParams(NamedTuple):
    """The RUNTIME half of :class:`FLConfig` — a pytree of scalars the
    compiled round step takes as an argument instead of closing over.

    Every field is a plain float (host construction) or a 0-d/`[lanes]`
    ``jnp`` array (inside the engine); the round step never branches on
    them, so one compiled program serves any values — and a stacked
    ``[lanes]`` axis of them turns an entire hyper-parameter sweep into one
    vmapped program (``train/fl_driver.run_fl_sweep``).
    """

    local_lr: float = 0.05
    server_lr: float = 1.0
    dp_epsilon: float = 8.0
    dp_sigma: float = 0.01
    dp_clip: float = 1.0
    dp_budget: float = 50.0
    dp_sched: float = 0.0
    dp_sched_rate: float = 0.3
    dp_stall_tol: float = 1e-3
    failure_prob: float = 0.05
    fault_process: float = 0.0
    fault_burst: float = 3.0
    straggler_slow: float = 4.0
    fault_util_w: float = 0.0
    weibull_shape: float = 1.2
    recovery_time: float = 30.0
    avail_prob: float = 0.95
    explore_noise: float = 0.05
    k_tol: float = 1e-3
    k_patience: float = 3.0
    async_buffer: float = 0.0
    async_staleness_pow: float = 0.5
    hier_comm_frac: float = 0.3
    # DERIVED lane code, not an FLConfig field: fl_params computes it from
    # the STATIC plan name via the core/plans.py registry (0 sync flat |
    # 1 buffered_async | 2 hierarchical), the same trick fault_process /
    # dp_sched use to keep a categorical choice on the runtime lane axis.
    plan_code: float = 0.0


# FLConfig fields mirrored by FLParams (single source of truth for the
# static/runtime split — fl_params/fl_static derive from this tuple).
# plan_code is derived from the plan name, not mirrored.
RUNTIME_FIELDS = tuple(f for f in FLParams._fields if f != "plan_code")


def fl_params(fl: FLConfig) -> FLParams:
    """Extract the runtime knobs of ``fl`` as an :class:`FLParams` pytree.

    ``plan_code`` is derived from the STATIC plan name (core/plans.py):
    the name picks the program family, the code picks the lane within it.
    """
    from repro.core.plans import plan_code
    return FLParams(plan_code=plan_code(fl.plan),
                    **{f: getattr(fl, f) for f in RUNTIME_FIELDS})


def fl_static(fl: FLConfig) -> FLConfig:
    """Canonical STATIC part of ``fl``: every runtime field reset to its
    dataclass default AND the plan name canonicalised to its program
    family.  Two configs that differ only in runtime knobs — or in
    same-family plans (client_parallel vs buffered_async vs hierarchical;
    the concrete plan is the runtime ``plan_code`` lane) — map to the same
    static config, so the compiled-program cache serves a whole
    plan × ε × failure grid from one entry."""
    from repro.core.plans import plan_family
    defaults = {f: FLConfig.__dataclass_fields__[f].default
                for f in RUNTIME_FIELDS}
    return dataclasses.replace(fl, plan=plan_family(fl.plan), **defaults)


# ---------------------------------------------------------------------------
# Mesh / run configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False

    @property
    def shape(self) -> Tuple[int, ...]:
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self) -> Tuple[str, ...]:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    mesh: MeshConfig = field(default_factory=MeshConfig)
    fl: FLConfig = field(default_factory=FLConfig)
    # remat policy for the layer scan: "full" | "dots" | "none"
    remat: str = "full"
    # microbatches for gradient accumulation inside train_step
    grad_accum: int = 1
    attention_impl: str = "ref"  # ref | flash (pallas)

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "phi3p5_moe_42b",
    "llama4_maverick_400b",
    "recurrentgemma_9b",
    "mamba2_130m",
    "seamless_m4t_large_v2",
    "mistral_large_123b",
    "qwen2_vl_72b",
    "qwen2p5_32b",
    "granite_3_8b",
    "phi3_mini_3p8b",
)

# user-facing aliases (--arch accepts either)
ARCH_ALIASES = {
    "phi3.5-moe-42b-a6.6b": "phi3p5_moe_42b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "mamba2-130m": "mamba2_130m",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "mistral-large-123b": "mistral_large_123b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "qwen2.5-32b": "qwen2p5_32b",
    "granite-3-8b": "granite_3_8b",
    "phi3-mini-3.8b": "phi3_mini_3p8b",
    "paper-mlp": "paper_mlp",
}


def get_arch(arch: str, smoke: bool = False) -> ModelConfig:
    """Load ``config()`` (or ``smoke_config()``) from repro.configs.<arch>."""
    arch = ARCH_ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.smoke_config() if smoke else mod.config()


def get_shape(name: str) -> ShapeConfig:
    return INPUT_SHAPES[name]


def all_pairs() -> Sequence[Tuple[str, str]]:
    """Every assigned (architecture x input shape) combination (40)."""
    return [(a, s) for a in ARCH_IDS for s in INPUT_SHAPES]
