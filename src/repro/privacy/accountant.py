"""Rényi-DP accounting for the subsampled Gaussian mechanism.

The privacy subsystem's source of truth (PR 3).  Two faces of the same
math:

* **Host (NumPy, f64)** — :class:`RdpAccountant`, :func:`compose_epsilon`,
  :func:`noise_multiplier_for_budget`, :func:`accounted_epsilon`: exact
  composition for reporting, calibration and offline verification.  These
  used to live in ``core/dp.py``; that module re-exports them unchanged.
* **In-scan (jnp, f32)** — :class:`AccountantState` +
  :func:`accountant_step` + :func:`epsilon_from_state`: the accountant as
  a ``lax.scan`` carry.  The noise multiplier ``z`` and sampling fraction
  ``q`` may be traced per-round values (scheduler output, adaptive-K
  cohort size), so one compiled program accounts any schedule.  The RDP
  vector is accumulated with Neumaier-compensated summation (two f32
  arrays), keeping the composed sum accurate to one f32 rounding of the
  total over hundreds of rounds; the order-dependent conversion constants
  are folded on the host in f64.  ``tests/test_privacy.py`` pins the
  in-scan ε against an independent f64 reference at 1e-6.

RDP of one release of the Gaussian mechanism at order α: ``α / (2 z²)``;
with Poisson-style subsampling at fraction q we use the small-q
amplification bound ``min(α/(2z²), 2 q² α / z²)`` (never worse than no
amplification).  Conversion to (ε, δ) uses the tightened bound
``ε = RDP(α) + log1p(-1/α) − (log δ + log α)/(α−1)`` minimised over a
fixed order grid.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Order grid shared by every accountant (host and in-scan).
ORDERS = tuple([1.25, 1.5, 2.0, 2.5, 3.0, 4.0, 5.0, 6.0, 8.0, 10.0, 12.0,
                16.0, 20.0, 32.0, 48.0, 64.0, 128.0, 256.0])


# ---------------------------------------------------------------------------
# Host side (NumPy, f64) — reporting, calibration, offline verification
# ---------------------------------------------------------------------------


def rdp_gaussian(noise_multiplier: float, orders=ORDERS) -> np.ndarray:
    """RDP of one Gaussian release: eps(alpha) = alpha / (2 z^2)."""
    a = np.asarray(orders, dtype=np.float64)
    return a / (2.0 * noise_multiplier**2)


def rdp_subsampled_gaussian(noise_multiplier: float, q: float,
                            orders=ORDERS) -> np.ndarray:
    """Cheap upper bound on RDP with sampling fraction q.

    Uses eps'(alpha) <= min(eps(alpha), 2 q^2 alpha / z^2) — the small-q
    amplification bound (valid for q·alpha ≲ z); we take the elementwise min
    with the unamplified value so it is never worse than no amplification.
    """
    base = rdp_gaussian(noise_multiplier, orders)
    a = np.asarray(orders, dtype=np.float64)
    amplified = 2.0 * (q**2) * a / (noise_multiplier**2)
    return np.minimum(base, amplified)


def conversion_consts(delta: float, orders=ORDERS) -> np.ndarray:
    """Order-dependent part of the RDP→(ε, δ) bound (f64, host-folded):
    ``log1p(-1/α) − (log δ + log α)/(α−1)``."""
    a = np.asarray(orders, dtype=np.float64)
    return np.log1p(-1.0 / a) - (np.log(delta) + np.log(a)) / (a - 1.0)


def rdp_to_dp(rdp: np.ndarray, delta: float, orders=ORDERS) -> Tuple[float, float]:
    """Convert composed RDP curve to (epsilon, best_order)."""
    a = np.asarray(orders, dtype=np.float64)
    eps = rdp + conversion_consts(delta, orders)
    i = int(np.argmin(eps))
    return float(eps[i]), float(a[i])


class RdpAccountant:
    """Tracks cumulative privacy loss over communication rounds (host)."""

    def __init__(self, delta: float, orders=ORDERS):
        self.delta = delta
        self.orders = orders
        self._rdp = np.zeros(len(orders), dtype=np.float64)
        self.steps = 0

    def step(self, noise_multiplier: float, q: float = 1.0):
        if q >= 1.0:
            self._rdp += rdp_gaussian(noise_multiplier, self.orders)
        else:
            self._rdp += rdp_subsampled_gaussian(noise_multiplier, q,
                                                 self.orders)
        self.steps += 1

    def epsilon(self) -> float:
        if self.steps == 0:
            return 0.0
        return rdp_to_dp(self._rdp, self.delta, self.orders)[0]


def compose_epsilon(noise_multiplier: float, q: float, steps: int,
                    delta: float, orders=ORDERS) -> float:
    """Closed-form constant-z composition: ε after ``steps`` releases.

    Equivalent to ``steps`` :meth:`RdpAccountant.step` calls (the per-step
    RDP vector is constant), without the Python loop.
    """
    if steps <= 0:
        return 0.0
    rdp = steps * rdp_subsampled_gaussian(noise_multiplier, min(q, 1.0),
                                          orders)
    return rdp_to_dp(rdp, delta, orders)[0]


def noise_multiplier_for_budget(epsilon: float, delta: float, rounds: int,
                                q: float = 1.0) -> float:
    """Smallest z such that `rounds` compositions stay within (eps, delta).

    Geometric bisection over the closed-form composition; returns the side
    that satisfies the budget (ε(z) ≤ epsilon).
    """
    lo, hi = 1e-2, 1e4
    for _ in range(80):
        mid = math.sqrt(lo * hi)
        if compose_epsilon(mid, q, rounds, delta) > epsilon:
            lo = mid
        else:
            hi = mid
    return hi


def accounted_epsilon(fl, rounds: int) -> float:
    """DP budget actually spent by a fixed-σ run of ``rounds`` rounds of
    ``fl`` (an :class:`repro.configs.base.FLConfig`) — the accountant-backed
    replacement for the old ``fl_driver.spent_epsilon``.

    Scheduled runs (``fl.dp_scheduled``) vary σ and the cohort per round, so
    their ε comes from the in-scan accountant's trace, not from here.
    """
    if not fl.dp_enabled:
        return 0.0
    if fl.dp_scheduled:
        raise ValueError(
            "dp_scheduled runs report ε from the in-scan accountant "
            "(RunResult.history['eps']), not from a host-side closed form")
    from repro.core import dp as dp_lib  # local: core/dp re-exports us

    sigma = (fl.dp_sigma if fl.dp_mode == "paper"
             else dp_lib.gaussian_sigma(fl.dp_epsilon, fl.dp_delta, fl.dp_clip))
    q = fl.clients_per_round / fl.n_clients
    z = max(sigma / max(fl.dp_clip, 1e-9), 1e-3)
    return compose_epsilon(z, q, rounds, fl.dp_delta)


# ---------------------------------------------------------------------------
# In-scan side (jnp, f32) — the accountant as a lax.scan carry
# ---------------------------------------------------------------------------


class AccountantState(NamedTuple):
    """Cumulative RDP curve, carried through the compiled round loop.

    ``rdp``/``rdp_c`` are the Neumaier-compensated (sum, carry) pair per
    order — ``rdp + rdp_c`` is the composed RDP accurate to ~1 ulp of the
    total in f32.  All leaves are jnp arrays, so the state vmaps over sweep
    lanes like any other carry.
    """

    rdp: jnp.ndarray     # [n_orders] f32 — running sum
    rdp_c: jnp.ndarray   # [n_orders] f32 — compensation carry
    steps: jnp.ndarray   # i32 scalar — committed releases


def init_accountant_state(orders=ORDERS) -> AccountantState:
    n = len(orders)
    return AccountantState(
        rdp=jnp.zeros((n,), jnp.float32),
        rdp_c=jnp.zeros((n,), jnp.float32),
        steps=jnp.zeros((), jnp.int32),
    )


def rdp_increment(noise_multiplier, q, orders=ORDERS) -> jnp.ndarray:
    """One release's RDP vector; ``noise_multiplier``/``q`` may be traced
    (per-round scheduler output / adaptive-K cohort fraction).  At q = 1 the
    amplified term is never the min, so the elementwise minimum reproduces
    the host accountant's q ≥ 1 branch without a trace-unfriendly cond."""
    a = jnp.asarray(np.asarray(orders, np.float64), jnp.float32)
    z2 = jnp.square(jnp.maximum(noise_multiplier, 1e-6))
    base = a / (2.0 * z2)
    amplified = 2.0 * jnp.square(q) * a / z2
    return jnp.minimum(base, amplified)


def accountant_step(state: AccountantState, noise_multiplier, q,
                    orders=ORDERS) -> AccountantState:
    """Compose one release into the carried state (Neumaier two-sum)."""
    inc = rdp_increment(noise_multiplier, q, orders)
    s = state.rdp + inc
    larger = jnp.abs(state.rdp) >= jnp.abs(inc)
    big = jnp.where(larger, state.rdp, inc)
    small = jnp.where(larger, inc, state.rdp)
    return AccountantState(
        rdp=s,
        rdp_c=state.rdp_c + ((big - s) + small),
        steps=state.steps + 1,
    )


def epsilon_from_state(state: AccountantState, delta: float,
                       orders=ORDERS) -> jnp.ndarray:
    """(ε, δ)-conversion of the carried RDP curve — called on eval
    boundaries (and for the exhaustion check).  ``delta`` is static, so the
    order constants fold on the host in f64."""
    const = jnp.asarray(conversion_consts(delta, orders), jnp.float32)
    eps = (state.rdp + state.rdp_c) + const
    return jnp.where(state.steps > 0, jnp.min(eps), 0.0)


def composed_epsilon_rt(noise_multiplier, q, steps, delta: float,
                        orders=ORDERS) -> jnp.ndarray:
    """Trace-safe constant-z composition (jnp twin of
    :func:`compose_epsilon`): ``steps`` is static, ``z``/``q`` may be
    traced.  Used by the scheduler's budget calibration."""
    const = jnp.asarray(conversion_consts(delta, orders), jnp.float32)
    eps = steps * rdp_increment(noise_multiplier, q, orders) + const
    return jnp.min(eps)


def noise_multiplier_for_budget_rt(epsilon, delta: float, rounds: int, q,
                                   iters: int = 60) -> jnp.ndarray:
    """Trace-safe twin of :func:`noise_multiplier_for_budget`: geometric
    bisection under ``jit`` — ``epsilon`` (the total budget) and ``q`` may
    be traced sweep lanes, so a whole budget grid calibrates inside one
    compiled program.  Returns the budget-satisfying side."""
    def body(_, lo_hi):
        lo, hi = lo_hi
        mid = jnp.sqrt(lo * hi)
        over = composed_epsilon_rt(mid, q, rounds, delta) > epsilon
        return jnp.where(over, mid, lo), jnp.where(over, hi, mid)

    lo0 = jnp.asarray(1e-2, jnp.float32)
    hi0 = jnp.asarray(1e4, jnp.float32)
    _, hi = jax.lax.fori_loop(0, iters, body, (lo0 + 0.0 * epsilon,
                                               hi0 + 0.0 * epsilon))
    return hi
