"""Privacy-budget schedulers: how a total (ε, δ) budget is spent per round.

A scheduler turns the runtime budget knobs (``FLParams.dp_budget``,
``dp_sched``, ``dp_sched_rate``, ``dp_stall_tol``) into a per-round noise
multiplier ``z_t`` (σ_t = z_t · clip).  Three schedules, all computed
branch-free and selected by the runtime code ``dp_sched`` — the schedule
choice is a **sweep lane**, not a compile-time option:

* ``uniform``  (0) — constant z, calibrated so the composed ε over the
  planned rounds meets the budget exactly
  (:func:`~repro.privacy.accountant.noise_multiplier_for_budget_rt`).
* ``linear``   (1) — noise decays linearly from ``(1+rate)·z`` to
  ``(1−rate)·z``: early rounds are cheap (model far from converged), late
  rounds spend more budget where precision matters.
* ``adaptive`` (2) — starts at the uniform z and *spends more budget /
  less noise when validation AUC stalls*, mirroring the paper's adaptive-K
  plateau logic: each non-improving eval block multiplies the noise by
  ``(1 − rate)`` (floored), so a stalled model trades remaining budget for
  signal.

Schedules other than ``uniform`` deliberately leave exact calibration to
the **accountant + exhaustion masking** (`train/fl_driver.py`): the
in-scan accountant tracks the actual composed ε every round, and a round
whose release would overshoot ``dp_budget`` is withheld from the global
model — exactly how a deployment halts at budget exhaustion.  An adaptive
run that spends fast therefore exhausts (and freezes) early; a uniform run
exhausts on its final round by construction.

The scheduler state rides the ``lax.scan`` carry next to the
:class:`~repro.privacy.accountant.AccountantState`; updates happen on eval
boundaries only (AUC is computed there), so σ is piecewise-constant per
eval block and flows into the clip+noise kernels as a traced per-round
value — no recompiles anywhere in a (budget × schedule) sweep.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.privacy import accountant as acct_lib

# Runtime schedule codes (FLParams.dp_sched carries these as f32 lanes).
SCHEDULES = ("uniform", "linear", "adaptive")

# Adaptive floor: the noise never drops below this fraction of the
# calibrated base — one stall streak cannot blow the whole budget at once.
BOOST_FLOOR = 0.25


def schedule_code(name: str) -> float:
    """Runtime lane value for a schedule name."""
    return float(SCHEDULES.index(name))


class SchedulerState(NamedTuple):
    """Carried per lane through the compiled round loop (all f32)."""

    z_base: jnp.ndarray    # budget-calibrated base noise multiplier
    boost: jnp.ndarray     # adaptive noise factor in [BOOST_FLOOR, 1]
    best_auc: jnp.ndarray  # best validation AUC seen (stall detector)


def init_scheduler(budget, delta: float, rounds: int, q) -> SchedulerState:
    """Calibrate the base multiplier for ``budget`` over ``rounds`` planned
    releases at nominal sampling fraction ``q`` (both may be traced sweep
    lanes) and start the adaptive controller at no boost."""
    z = acct_lib.noise_multiplier_for_budget_rt(budget, delta, rounds, q)
    one = jnp.ones((), jnp.float32)
    zero = jnp.zeros((), jnp.float32)
    return SchedulerState(z_base=z, boost=one, best_auc=zero)


def scheduled_multiplier(state: SchedulerState, pr, round_idx,
                         rounds: int) -> jnp.ndarray:
    """Per-round noise multiplier z_t.  ``pr`` is the runtime
    :class:`~repro.configs.base.FLParams`; ``round_idx`` is the traced
    round counter; ``rounds`` the static plan length.  All three schedules
    are cheap scalar math, so every branch is computed and the runtime
    ``dp_sched`` code selects — a schedule sweep shares one program."""
    t = round_idx.astype(jnp.float32) / float(max(rounds - 1, 1))
    z_uniform = state.z_base
    z_linear = state.z_base * (1.0 + pr.dp_sched_rate * (1.0 - 2.0 * t))
    z_adaptive = state.z_base * state.boost
    sched = pr.dp_sched
    z = jnp.where(sched < 0.5, z_uniform,
                  jnp.where(sched < 1.5, z_linear, z_adaptive))
    return jnp.maximum(z, 1e-3)


def scheduler_update(state: SchedulerState, auc, pr) -> SchedulerState:
    """Eval-boundary update (the only place AUC exists).  The adaptive-K
    plateau rule transplanted to the privacy axis, at eval-block
    granularity: a block whose AUC fails to beat the best seen by
    ``dp_stall_tol`` is a stall, and every stalled block shrinks the
    adaptive noise factor by ``(1 − dp_sched_rate)`` down to
    :data:`BOOST_FLOOR` — spend more budget when progress stops.  The
    patience is one eval block, i.e. ``eval_every`` ROUNDS of no progress
    (AUC only exists per block, so that is the finest plateau the engine
    can observe).  Uniform/linear lanes carry the same state but never
    read ``boost``."""
    improved = auc > state.best_auc + pr.dp_stall_tol
    boost = jnp.where(
        improved, state.boost,
        jnp.maximum(state.boost * (1.0 - pr.dp_sched_rate), BOOST_FLOOR))
    return SchedulerState(
        z_base=state.z_base,
        boost=boost,
        best_auc=jnp.maximum(state.best_auc, auc),
    )
