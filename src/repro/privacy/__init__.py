"""Privacy accounting subsystem (PR 3).

* :mod:`repro.privacy.accountant` — Rényi-DP accounting for the
  subsampled Gaussian mechanism: host-side f64 composition/calibration and
  the jit-safe :class:`AccountantState` carried through the compiled round
  loop.
* :mod:`repro.privacy.schedule` — budget schedulers (uniform / linear /
  adaptive) selected by a runtime lane code, plus the stall-driven
  adaptive controller updated on eval boundaries.

Budget-exhaustion semantics live in the engine: `core/rounds.py` masks the
server update for a round whose release would overshoot the budget, and
`train/fl_driver.py` carries the accountant/scheduler state and emits the
accounted ε into the eval trace.  See docs/ARCHITECTURE.md §Privacy.
"""
from repro.privacy.accountant import (AccountantState, ORDERS,  # noqa: F401
                                      RdpAccountant, accountant_step,
                                      accounted_epsilon, compose_epsilon,
                                      composed_epsilon_rt,
                                      epsilon_from_state,
                                      init_accountant_state,
                                      noise_multiplier_for_budget,
                                      noise_multiplier_for_budget_rt,
                                      rdp_gaussian, rdp_increment,
                                      rdp_subsampled_gaussian, rdp_to_dp)
from repro.privacy.schedule import (BOOST_FLOOR, SCHEDULES,  # noqa: F401
                                    SchedulerState, init_scheduler,
                                    schedule_code, scheduled_multiplier,
                                    scheduler_update)
