"""StatsRegistry — one snapshot/reset/assert API over the repo's counters.

The repo grew ad-hoc module-level counter dicts as it grew subsystems:
``fl_driver.RUNNER_STATS`` (PR 2) and ``serve.engine.SERVE_STATS`` (PR 7)
are both ``{"misses": 0, "hits": 0}`` with the same discipline — benches
and tests snapshot them, run something, and assert the delta (the
single-compile property).  This module absorbs them behind ONE registry
without breaking a single call site: a :class:`Counters` namespace is a
``MutableMapping``, so ``RUNNER_STATS["misses"] += 1``,
``dict(RUNNER_STATS)`` and ``RUNNER_STATS["misses"] - m0`` all behave
exactly like the plain dicts they replace.

What the registry adds on top:

* ``STATS.snapshot()`` — every namespace at once (one dict, JSON-safe);
* ``STATS.reset()`` — restore declared defaults (per namespace or all);
* ``STATS.delta(ns)`` / ``STATS.expect(ns, **deltas)`` — context managers
  for the snapshot/run/assert idiom the benches repeat by hand.

Everything here is host-side Python; nothing touches a traced value.
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Dict, Iterator, MutableMapping


class Counters(MutableMapping):
    """A named counter namespace: dict-compatible (the legacy call sites
    index, iterate and copy it) with declared defaults for reset."""

    __slots__ = ("name", "_data", "_defaults")

    def __init__(self, name: str, **defaults: int):
        self.name = name
        self._defaults = dict(defaults)
        self._data: Dict[str, int] = dict(defaults)

    def __getitem__(self, key: str) -> int:
        return self._data[key]

    def __setitem__(self, key: str, value: int) -> None:
        self._data[key] = value

    def __delitem__(self, key: str) -> None:
        del self._data[key]

    def __iter__(self) -> Iterator[str]:
        return iter(self._data)

    def __len__(self) -> int:
        return len(self._data)

    def __repr__(self) -> str:
        return f"Counters({self.name!r}, {self._data})"

    def reset(self) -> None:
        """Restore the declared defaults (unknown keys are dropped)."""
        self._data = dict(self._defaults)


class StatsRegistry:
    """The process-wide registry of counter namespaces."""

    def __init__(self):
        self._namespaces: Dict[str, Counters] = {}

    def counters(self, namespace: str, **defaults: int) -> Counters:
        """The namespace's :class:`Counters`, created with ``defaults`` on
        first use.  Repeat calls return the SAME object (module-level
        aliases like ``RUNNER_STATS`` stay views of registry state), and
        later defaults are merged without clobbering live counts."""
        ns = self._namespaces.get(namespace)
        if ns is None:
            ns = Counters(namespace, **defaults)
            self._namespaces[namespace] = ns
        else:
            for k, v in defaults.items():
                ns._defaults.setdefault(k, v)
                ns._data.setdefault(k, v)
        return ns

    def snapshot(self) -> Dict[str, Dict[str, int]]:
        """Every namespace's current counts (plain nested dicts)."""
        return {name: dict(ns) for name, ns in self._namespaces.items()}

    def reset(self, namespace: str | None = None) -> None:
        if namespace is not None:
            self._namespaces[namespace].reset()
            return
        for ns in self._namespaces.values():
            ns.reset()

    @contextmanager
    def delta(self, namespace: str):
        """``with STATS.delta("runner") as d: ...`` — ``d`` fills with the
        per-key change over the block at exit (keys that did not move are
        reported as 0)."""
        ns = self.counters(namespace)
        before = dict(ns)
        out: Dict[str, int] = {}
        yield out
        for k, v in ns.items():
            out[k] = v - before.get(k, 0)

    @contextmanager
    def expect(self, namespace: str, **expected: int):
        """Assert exact per-key deltas over the block — the benches'
        single-compile idiom (``misses=1``) as one line."""
        with self.delta(namespace) as d:
            yield
        for k, want in expected.items():
            got = d.get(k, 0)
            assert got == want, (
                f"stats[{namespace}].{k}: expected delta {want}, got {got} "
                f"(full delta {d})")


# The process-wide registry.  Subsystems register their namespaces at
# import time (fl_driver: "runner"; serve.engine: "serve").
STATS = StatsRegistry()
