"""Unified observability layer (ISSUE 8, docs/DESIGN.md §8).

Three coupled pieces, all HOST-side — nothing here enters a traced
function, so every training/eval lane is bitwise identical with telemetry
on or off (tests/test_obs.py pins it):

* ``obs/trace.py`` — nested host spans (wall + process time), compile-event
  capture keyed on the runner/scorer caches, structured JSONL emission, and
  the ``jax.profiler`` integration (``profile_trace`` dumps a
  TensorBoard-loadable trace; spans double as
  ``jax.profiler.TraceAnnotation`` phase markers while profiling).
* ``obs/stats.py`` — the :class:`StatsRegistry`: one snapshot/reset/assert
  API over every ad-hoc counter dict the repo grew
  (``fl_driver.RUNNER_STATS``, ``serve.engine.SERVE_STATS`` are registry
  views now — their dict-style call sites work unchanged).
* ``obs/store.py`` — the embedded indexed experiment store (single-file
  SQLite, append-only runs/cells/metrics) every bench writes through;
  ``tools/bench_regress.py`` queries its history for CI regression gates.
"""
from repro.obs.stats import STATS, Counters, StatsRegistry
from repro.obs.trace import (TRACER, Tracer, event, profile_trace, span,
                             spans)
from repro.obs.store import ExperimentStore, default_store, default_store_path

__all__ = [
    "STATS", "Counters", "StatsRegistry",
    "TRACER", "Tracer", "event", "profile_trace", "span", "spans",
    "ExperimentStore", "default_store", "default_store_path",
]
