"""The embedded experiment store: indexed SQLite over every bench cell.

Seven ``BENCH_*.json`` snapshots could answer "what did the LAST run
measure" and nothing else (ROADMAP open item 3).  This store records every
bench cell append-only, so the repo can finally ask trajectory questions —
"warm wall across ENGINE_REV for the sweep lane", "AUC history of the cnn
road_raw cell" — and CI can gate on them (``tools/bench_regress.py``).

Schema (single file, stdlib ``sqlite3``, no dependencies):

* ``runs``    — one row per bench process: timestamp, git SHA, ENGINE_REV,
  backend, mode (smoke/full), free-form note.
* ``cells``   — one row per measured bench cell: (bench, lane_key) names
  the measurement, ``statics_key`` fingerprints the compiled-program
  statics (a lane only compares against history of the SAME program
  family), cold/warm walls with the full min-of-N wall list (the
  regression gate's Mann-Whitney samples), and the lane's runtime params
  as JSON.
* ``metrics`` — named scalars per cell with a ``direction``:
  ``+1`` higher-is-better (gated), ``-1`` lower-is-better (gated),
  ``0`` informational.

Indexed on ``(bench, engine_rev, statics_key, lane_key)`` — the regression
gate's exact lookup — plus ``run_id`` for per-run scans.  Writes are
append-only: nothing in the repo ever UPDATEs or DELETEs a row, so the
history a gate reads is immutable.
"""
from __future__ import annotations

import json
import os
import sqlite3
import subprocess
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

_SCHEMA = """
CREATE TABLE IF NOT EXISTS runs (
  run_id     INTEGER PRIMARY KEY AUTOINCREMENT,
  ts         REAL NOT NULL,
  git_sha    TEXT,
  engine_rev TEXT,
  backend    TEXT,
  mode       TEXT,
  note       TEXT
);
CREATE TABLE IF NOT EXISTS cells (
  cell_id     INTEGER PRIMARY KEY AUTOINCREMENT,
  run_id      INTEGER NOT NULL REFERENCES runs(run_id),
  bench       TEXT NOT NULL,
  lane_key    TEXT NOT NULL,
  statics_key TEXT NOT NULL DEFAULT '',
  engine_rev  TEXT,
  git_sha     TEXT,
  ts          REAL NOT NULL,
  wall_cold_s REAL,
  wall_warm_s REAL,
  warm_n      INTEGER,
  warm_walls  TEXT,
  lane_params TEXT
);
CREATE TABLE IF NOT EXISTS metrics (
  cell_id   INTEGER NOT NULL REFERENCES cells(cell_id),
  name      TEXT NOT NULL,
  value     REAL,
  direction INTEGER NOT NULL DEFAULT 0,
  PRIMARY KEY (cell_id, name)
);
CREATE INDEX IF NOT EXISTS idx_cells_key
  ON cells(bench, engine_rev, statics_key, lane_key);
CREATE INDEX IF NOT EXISTS idx_cells_run ON cells(run_id);
"""

MetricValue = Union[float, Tuple[float, int]]


def git_sha(root: Optional[str] = None) -> str:
    """Current commit SHA (short), or "unknown" outside a git checkout."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=root or os.path.dirname(os.path.abspath(__file__)))
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except OSError:
        return "unknown"


class ExperimentStore:
    """Append-only indexed store over one SQLite file."""

    def __init__(self, path: str):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._conn = sqlite3.connect(path)
        self._conn.row_factory = sqlite3.Row
        self._conn.executescript(_SCHEMA)
        self._conn.commit()

    def close(self) -> None:
        self._conn.close()

    # -- writes (append-only) ---------------------------------------------

    def begin_run(self, engine_rev: str = "", backend: str = "",
                  mode: str = "", note: str = "",
                  sha: Optional[str] = None) -> int:
        cur = self._conn.execute(
            "INSERT INTO runs (ts, git_sha, engine_rev, backend, mode, note)"
            " VALUES (?, ?, ?, ?, ?, ?)",
            (time.time(), sha if sha is not None else git_sha(),
             engine_rev, backend, mode, note))
        self._conn.commit()
        return int(cur.lastrowid)

    def record_cell(self, run_id: int, bench: str, lane_key: str, *,
                    statics_key: str = "",
                    wall_cold_s: Optional[float] = None,
                    wall_warm_s: Optional[float] = None,
                    warm_walls: Optional[Sequence[float]] = None,
                    lane_params: Optional[Dict[str, Any]] = None,
                    metrics: Optional[Dict[str, MetricValue]] = None) -> int:
        """One measured cell.  ``warm_walls`` is the full min-of-N list (the
        regression gate's samples); ``wall_warm_s`` defaults to its min.
        ``metrics`` values are either a bare float (informational) or a
        ``(value, direction)`` pair (+1 higher-better / -1 lower-better
        marks the metric GATED for ``tools/bench_regress.py``)."""
        row = self._conn.execute(
            "SELECT git_sha, engine_rev FROM runs WHERE run_id = ?",
            (run_id,)).fetchone()
        if row is None:
            raise ValueError(f"unknown run_id {run_id}")
        if wall_warm_s is None and warm_walls:
            wall_warm_s = min(warm_walls)
        cur = self._conn.execute(
            "INSERT INTO cells (run_id, bench, lane_key, statics_key,"
            " engine_rev, git_sha, ts, wall_cold_s, wall_warm_s, warm_n,"
            " warm_walls, lane_params)"
            " VALUES (?, ?, ?, ?, ?, ?, ?, ?, ?, ?, ?, ?)",
            (run_id, bench, lane_key, statics_key, row["engine_rev"],
             row["git_sha"], time.time(), wall_cold_s, wall_warm_s,
             len(warm_walls) if warm_walls else None,
             json.dumps([float(w) for w in warm_walls]) if warm_walls
             else None,
             json.dumps(lane_params) if lane_params else None))
        cell_id = int(cur.lastrowid)
        for name, v in (metrics or {}).items():
            value, direction = v if isinstance(v, tuple) else (v, 0)
            self._conn.execute(
                "INSERT INTO metrics (cell_id, name, value, direction)"
                " VALUES (?, ?, ?, ?)",
                (cell_id, name, None if value is None else float(value),
                 int(direction)))
        self._conn.commit()
        return cell_id

    # -- queries ----------------------------------------------------------

    @staticmethod
    def _cell_dict(row: sqlite3.Row) -> Dict[str, Any]:
        d = dict(row)
        d["warm_walls"] = (json.loads(d["warm_walls"])
                           if d.get("warm_walls") else [])
        d["lane_params"] = (json.loads(d["lane_params"])
                            if d.get("lane_params") else {})
        return d

    def _attach_metrics(self, cells: List[Dict[str, Any]]) -> None:
        for c in cells:
            c["metrics"] = {
                r["name"]: {"value": r["value"],
                            "direction": r["direction"]}
                for r in self._conn.execute(
                    "SELECT name, value, direction FROM metrics"
                    " WHERE cell_id = ?", (c["cell_id"],))}

    def latest_run_id(self) -> Optional[int]:
        row = self._conn.execute("SELECT MAX(run_id) m FROM runs").fetchone()
        return int(row["m"]) if row and row["m"] is not None else None

    def run_ids(self) -> List[int]:
        return [int(r["run_id"]) for r in self._conn.execute(
            "SELECT run_id FROM runs ORDER BY run_id")]

    def cells_of_run(self, run_id: int) -> List[Dict[str, Any]]:
        cells = [self._cell_dict(r) for r in self._conn.execute(
            "SELECT * FROM cells WHERE run_id = ? ORDER BY cell_id",
            (run_id,))]
        self._attach_metrics(cells)
        return cells

    def history(self, bench: str, lane_key: str, *,
                engine_rev: Optional[str] = None,
                statics_key: Optional[str] = None,
                before_run: Optional[int] = None) -> List[Dict[str, Any]]:
        """Every recorded cell of (bench, lane_key), oldest first — the
        indexed lookup the regression gate and trajectory queries use.
        ``engine_rev``/``statics_key`` restrict to one program family;
        ``before_run`` excludes the current run (gate = history vs now)."""
        q = ("SELECT * FROM cells WHERE bench = ? AND lane_key = ?")
        args: List[Any] = [bench, lane_key]
        if engine_rev is not None:
            q += " AND engine_rev = ?"
            args.append(engine_rev)
        if statics_key is not None:
            q += " AND statics_key = ?"
            args.append(statics_key)
        if before_run is not None:
            q += " AND run_id < ?"
            args.append(before_run)
        q += " ORDER BY run_id, cell_id"
        cells = [self._cell_dict(r) for r in self._conn.execute(q, args)]
        self._attach_metrics(cells)
        return cells

    def metric_history(self, bench: str, lane_key: str, metric: str, *,
                       engine_rev: Optional[str] = None
                       ) -> List[Tuple[int, float]]:
        """``[(run_id, value), ...]`` oldest-first — e.g. the AUC or
        warm-wall trajectory across stored runs for one lane."""
        out = []
        for c in self.history(bench, lane_key, engine_rev=engine_rev):
            if metric == "wall_warm_s":
                v = c.get("wall_warm_s")
            else:
                m = c["metrics"].get(metric)
                v = m["value"] if m else None
            if v is not None:
                out.append((int(c["run_id"]), float(v)))
        return out

    def metric_trajectory(self, bench: str, lane_key: str, metric: str
                          ) -> List[Tuple[int, str, float]]:
        """``[(run_id, engine_rev, value), ...]`` oldest-first ACROSS engine
        revisions — the "AUC trajectory across ENGINE_REV" report (ROADMAP):
        unlike :meth:`metric_history` (which restricts to one rev so the
        regression gate compares like with like), this deliberately spans
        every rev so a metric can be followed through engine rewrites —
        each point is labelled with the rev that produced it, because a
        jump at a rev boundary is an engine change, not a regression."""
        out = []
        for c in self.history(bench, lane_key):
            if metric == "wall_warm_s":
                v = c.get("wall_warm_s")
            else:
                m = c["metrics"].get(metric)
                v = m["value"] if m else None
            if v is not None:
                out.append((int(c["run_id"]), c.get("engine_rev") or "",
                            float(v)))
        return out

    def trajectory_report(self, bench: str, metric: str) -> str:
        """Human-readable ``metric_trajectory`` over every lane of a bench
        (``tools/metric_trajectory.py`` CLI): one block per lane, one line
        per stored run, engine-rev labelled, with the delta vs the
        previous point."""
        lines = [f"== {bench}: {metric} trajectory across ENGINE_REV =="]
        for _, lane in self.lanes(bench):
            traj = self.metric_trajectory(bench, lane, metric)
            if not traj:
                continue
            lines.append(f"  {lane}:")
            prev = None
            for run_id, rev, v in traj:
                delta = "" if prev is None else f"  ({v - prev:+.4f})"
                lines.append(f"    run {run_id:>4d} [{rev or '?':>10s}]"
                             f"  {v:.4f}{delta}")
                prev = v
        if len(lines) == 1:
            lines.append(f"  (no stored cells carry metric {metric!r})")
        return "\n".join(lines)

    def lanes(self, bench: Optional[str] = None) -> List[Tuple[str, str]]:
        """Distinct (bench, lane_key) pairs recorded so far."""
        q = "SELECT DISTINCT bench, lane_key FROM cells"
        args: List[Any] = []
        if bench is not None:
            q += " WHERE bench = ?"
            args.append(bench)
        q += " ORDER BY bench, lane_key"
        return [(r["bench"], r["lane_key"])
                for r in self._conn.execute(q, args)]

    def query_plan_uses_index(self) -> bool:
        """True when the history lookup is answered via ``idx_cells_key``
        (tests assert the index actually serves the hot query)."""
        plan = self._conn.execute(
            "EXPLAIN QUERY PLAN SELECT * FROM cells WHERE bench = ?"
            " AND engine_rev = ? AND statics_key = ? AND lane_key = ?",
            ("b", "e", "s", "l")).fetchall()
        return any("idx_cells_key" in (r["detail"] or "") for r in plan)


def default_store_path() -> str:
    """``REPRO_STORE`` env override, else
    ``benchmarks/artifacts/experiments.sqlite`` at the repo root."""
    env = os.environ.get("REPRO_STORE")
    if env:
        return env
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    return os.path.join(root, "benchmarks", "artifacts",
                        "experiments.sqlite")


_DEFAULT: Optional[ExperimentStore] = None


def default_store() -> ExperimentStore:
    """The process-wide store at :func:`default_store_path` (opened once;
    re-opened if ``REPRO_STORE`` now points elsewhere)."""
    global _DEFAULT
    path = default_store_path()
    if _DEFAULT is None or _DEFAULT.path != path:
        _DEFAULT = ExperimentStore(path)
    return _DEFAULT
