"""Host-side tracer: nested spans, compile events, JSONL, profiler glue.

Design constraints (docs/DESIGN.md §8):

* **Bitwise neutrality** — nothing here runs inside a traced function.
  Spans time HOST phases (upload, dispatch, readback, compile) with wall
  and process clocks; device-side phase markers are ``jax.named_scope``
  annotations placed at the instrumentation sites themselves (metadata
  only — they never change the lowered math).
* **Zero cost when off** — the tracer is disabled by default and
  :func:`span` short-circuits to a shared no-op context manager, so the
  serving hot loop and the warm bench walls pay one attribute read per
  call site (the ≤5 % telemetry-overhead budget is measured with the
  tracer ON in benchmarks/bench_engine.py).
* **Structured emission** — spans/events append to an in-memory buffer
  and, when enabled with a path (or ``REPRO_TRACE=<path>`` in the
  environment), stream to JSONL one object per line: ``{"type": "span" |
  "event", "name", "t0", "wall_s", "cpu_s", "depth", "parent", ...attrs}``.
* **Profiler integration** — :func:`profile_trace` wraps
  ``jax.profiler.start_trace``/``stop_trace`` (the ``--profile`` flag on
  ``benchmarks/run.py`` and ``python -m repro.serve``); while profiling,
  every span ALSO enters a ``jax.profiler.TraceAnnotation`` so host phases
  line up with the device timeline in TensorBoard.
"""
from __future__ import annotations

import json
import os
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional


@dataclass
class Span:
    """One closed host span (or an open one still on the stack)."""

    name: str
    t0: float                      # time.time() at entry (epoch seconds)
    wall_s: float = 0.0            # perf_counter delta
    cpu_s: float = 0.0             # process_time delta
    depth: int = 0
    index: int = 0                 # position in the tracer's span list
    parent: int = -1               # index of the enclosing span (-1 = root)
    attrs: Dict[str, Any] = field(default_factory=dict)

    def to_json(self) -> Dict[str, Any]:
        return {"type": "span", "name": self.name, "t0": self.t0,
                "wall_s": self.wall_s, "cpu_s": self.cpu_s,
                "depth": self.depth, "index": self.index,
                "parent": self.parent, **self.attrs}


class _NullCm:
    """Reusable no-op context manager — the disabled fast path."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL = _NullCm()


class Tracer:
    """The host tracer.  One global instance (:data:`TRACER`); tests may
    construct private ones.  Thread-safe enough for the repo's use (the
    serving feed thread never opens spans; a lock guards the buffers)."""

    def __init__(self):
        self.enabled = False
        self.profiling = False
        self.spans: List[Span] = []
        self.events: List[Dict[str, Any]] = []
        self._stack: List[Span] = []
        self._lock = threading.Lock()
        self._jsonl = None          # open file handle when streaming

    # -- lifecycle --------------------------------------------------------

    def enable(self, jsonl_path: Optional[str] = None) -> None:
        """Turn span/event recording on; ``jsonl_path`` streams every
        closed span and event to disk as it happens."""
        with self._lock:
            if jsonl_path:
                d = os.path.dirname(jsonl_path)
                if d:
                    os.makedirs(d, exist_ok=True)
                self._jsonl = open(jsonl_path, "a")
            self.enabled = True

    def disable(self) -> None:
        with self._lock:
            self.enabled = False
            if self._jsonl is not None:
                self._jsonl.close()
                self._jsonl = None

    def clear(self) -> None:
        with self._lock:
            self.spans = []
            self.events = []
            self._stack = []

    # -- recording --------------------------------------------------------

    def span(self, name: str, **attrs):
        """Context manager timing a nested host phase.  No-op (shared null
        object, no allocation) while the tracer is disabled and no
        profiler trace is active."""
        if not (self.enabled or self.profiling):
            return _NULL
        return self._span_cm(name, attrs)

    @contextmanager
    def _span_cm(self, name: str, attrs: Dict[str, Any]):
        ann = None
        if self.profiling:          # host phase marker on the TB timeline
            import jax
            ann = jax.profiler.TraceAnnotation(name)
            ann.__enter__()
        if not self.enabled:        # profiling-only: annotate, don't record
            try:
                yield None
            finally:
                ann.__exit__(None, None, None)
            return
        with self._lock:
            sp = Span(name=name, t0=time.time(), depth=len(self._stack),
                      index=len(self.spans),
                      parent=self._stack[-1].index if self._stack else -1,
                      attrs=dict(attrs))
            self.spans.append(sp)
            self._stack.append(sp)
        w0, c0 = time.perf_counter(), time.process_time()
        try:
            yield sp
        finally:
            sp.wall_s = time.perf_counter() - w0
            sp.cpu_s = time.process_time() - c0
            with self._lock:
                if self._stack and self._stack[-1] is sp:
                    self._stack.pop()
                if self._jsonl is not None:
                    self._jsonl.write(json.dumps(sp.to_json()) + "\n")
                    self._jsonl.flush()
            if ann is not None:
                ann.__exit__(None, None, None)

    def event(self, name: str, **attrs) -> None:
        """Record a point event (e.g. a compile-cache miss).  No-op while
        disabled."""
        if not self.enabled:
            return
        with self._lock:
            ev = {"type": "event", "name": name, "t0": time.time(),
                  "depth": len(self._stack),
                  "parent": self._stack[-1].index if self._stack else -1,
                  **attrs}
            self.events.append(ev)
            if self._jsonl is not None:
                self._jsonl.write(json.dumps(ev) + "\n")
                self._jsonl.flush()

    # -- introspection ----------------------------------------------------

    def find(self, name: str) -> List[Span]:
        return [s for s in self.spans if s.name == name]

    def dump_jsonl(self, path: str) -> str:
        """Write the whole in-memory buffer to ``path`` (one JSON object
        per line, spans then events in record order)."""
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            for sp in self.spans:
                f.write(json.dumps(sp.to_json()) + "\n")
            for ev in self.events:
                f.write(json.dumps(ev) + "\n")
        return path


TRACER = Tracer()
if os.environ.get("REPRO_TRACE"):
    TRACER.enable(os.environ["REPRO_TRACE"])


def span(name: str, **attrs):
    """Module-level alias of :meth:`Tracer.span` on the global tracer —
    the instrumentation sites' one-liner."""
    return TRACER.span(name, **attrs)


def event(name: str, **attrs) -> None:
    TRACER.event(name, **attrs)


def spans(name: Optional[str] = None) -> List[Span]:
    return TRACER.find(name) if name else list(TRACER.spans)


@contextmanager
def profile_trace(logdir: str):
    """Dump a TensorBoard-loadable ``jax.profiler`` trace of the block to
    ``logdir`` (the ``--profile`` flag's implementation).  While active,
    host spans double as ``TraceAnnotation`` phase markers, and the
    device-side ``jax.named_scope`` markers (round_step / eval_block /
    cohort_topk / serve dispatch) appear in the XLA op names."""
    import jax

    os.makedirs(logdir, exist_ok=True)
    jax.profiler.start_trace(logdir)
    TRACER.profiling = True
    try:
        yield logdir
    finally:
        TRACER.profiling = False
        jax.profiler.stop_trace()
