"""Synthetic stand-ins for the paper's datasets (offline container).

* ``unsw_nb15_like`` — mirrors the UNSW-NB15 schema: 42 numeric flow
  features (durations, byte/packet counts, rates, TTLs, window sizes, ...)
  drawn from per-class lognormal/gamma/normal mixtures; 10 classes (normal +
  9 attack categories: fuzzers, analysis, backdoor, dos, exploits, generic,
  recon, shellcode, worms) with the published heavy class imbalance
  (~87.5% normal traffic).
* ``road_like`` — CAN-bus windows mimicking the ROAD *correlated masquerade*
  attack: per-ID correlated signal streams; an attack replays one signal's
  dynamics on another ID with a small offset — statistically stealthy, which
  is exactly the ROAD difficulty.

Non-IID federation: Dirichlet(α) label skew + per-client feature shift, as
assumed by the paper ("non-IID data distribution across clients").
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

UNSW_N_FEATURES = 42
UNSW_N_CLASSES = 10
UNSW_CLASS_PRIORS = np.array(
    [0.875, 0.024, 0.003, 0.002, 0.016, 0.044, 0.021, 0.010, 0.004, 0.001]
)
UNSW_CLASS_PRIORS = UNSW_CLASS_PRIORS / UNSW_CLASS_PRIORS.sum()


def unsw_nb15_like(rng: np.random.Generator, n: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Returns (X [n,42] float32 standardised, y_cat [n], y_bin [n])."""
    y = rng.choice(UNSW_N_CLASSES, size=n, p=UNSW_CLASS_PRIORS)
    X = np.empty((n, UNSW_N_FEATURES), np.float64)

    # class-conditional generative structure: each class shifts a subset of
    # features (e.g. DoS inflates packet rates; recon touches many ports)
    base_mu = rng.normal(0.0, 1.0, (UNSW_N_CLASSES, UNSW_N_FEATURES)) * 0.0
    cls_shift = rng.normal(0.0, 1.2, (UNSW_N_CLASSES, UNSW_N_FEATURES))
    cls_mask = rng.random((UNSW_N_CLASSES, UNSW_N_FEATURES)) < 0.25
    cls_shift = cls_shift * cls_mask
    cls_shift[0] = 0.0  # normal traffic is the reference

    # heavy-tailed "volume" features (bytes, packets, duration): lognormal
    heavy = np.zeros(UNSW_N_FEATURES, bool)
    heavy[:12] = True
    # rate-like features: gamma
    ratef = np.zeros(UNSW_N_FEATURES, bool)
    ratef[12:22] = True

    mu = base_mu[y] + cls_shift[y]
    z = rng.normal(0.0, 1.0, (n, UNSW_N_FEATURES))
    X = mu + z
    X[:, heavy] = np.exp(0.8 * X[:, heavy])  # lognormal tails
    X[:, ratef] = np.square(X[:, ratef])  # chi2-ish rates

    # correlated flow structure (shared latent per sample)
    latent = rng.normal(0.0, 1.0, (n, 4))
    mix = rng.normal(0.0, 0.4, (4, UNSW_N_FEATURES))
    X = X + latent @ mix

    # standardise
    X = (X - X.mean(0)) / (X.std(0) + 1e-9)
    return X.astype(np.float32), y.astype(np.int32), (y > 0).astype(np.int32)


def _corr_lastaxis(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Pearson correlation along the last axis (batched ``np.corrcoef``);
    0 where either side is (near-)constant."""
    ac = a - a.mean(-1, keepdims=True)
    bc = b - b.mean(-1, keepdims=True)
    sa = np.sqrt((ac * ac).mean(-1))
    sb = np.sqrt((bc * bc).mean(-1))
    denom = sa * sb
    safe = denom > 1e-18
    num = (ac * bc).mean(-1)
    return np.where(safe, num / np.where(safe, denom, 1.0), 0.0)


def _roll_lastaxis(x: np.ndarray, shift: np.ndarray) -> np.ndarray:
    """``np.roll`` along the last axis with a per-row shift (gather)."""
    window = x.shape[-1]
    idx = (np.arange(window)[None, :] - shift[:, None]) % window
    return np.take_along_axis(x, idx, axis=-1)


def road_like(
    rng: np.random.Generator,
    n: int,
    window: int = 64,
    n_signals: int = 6,
    attack_rate: float = 0.25,
    offset: float = 0.35,
    raw: bool = False,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Correlated-masquerade CAN windows.

    Normal windows: n_signals AR(1) streams with a shared low-frequency
    driver (vehicle state).  Attack: one signal is replaced by a *replay* of
    another signal's dynamics plus a small constant offset — the masquerade.
    Features: per-signal (mean, std, mean |Δ|, lag-1 autocorr, corr to
    signal 0) -> 5·n_signals features.
    Returns (X, y, y) — binary labels only (matches our ROAD use).

    ``raw=True`` skips the hand-engineered statistics and returns the
    standardised window matrix itself, flattened time-major to
    ``[n, window·n_signals]`` (reshape with ``feature_shape = (window,
    n_signals)`` recovers ``[window, n_signals]``) — the input the
    window-native detectors in ``models/detectors.py`` consume.  The RNG
    draw order is identical to the feature path, so raw and feature
    datasets of one seed describe the same windows.

    Fully vectorised across windows/signals (the per-window Python loop made
    this the hot spot of ``benchmarks/run.py``); only the AR(1) recursion
    iterates, over the ``window`` axis.  ``_road_like_loop`` keeps the
    original per-window implementation as the statistical oracle
    (tests/test_synthetic_road.py) — the two draw the RNG in different
    orders, so they match in distribution, not sample-for-sample.
    """
    y = (rng.random(n) < attack_rate).astype(np.int32)
    t = np.arange(window)

    # shared low-frequency driver per window: sin(2π t/window · f + φ0)
    freq = rng.uniform(0.5, 2.0, n)
    phase0 = rng.uniform(0, 6.28, n)
    driver = np.sin(2 * np.pi * t[None, :] / window * freq[:, None]
                    + phase0[:, None])                       # [n, window]

    phase = rng.uniform(0, 6.28, (n, n_signals))
    gain = rng.uniform(0.5, 1.5, (n, n_signals))
    ar = rng.uniform(0.7, 0.95, (n, n_signals))
    noise = rng.normal(0, 0.15, (n, n_signals, window))

    # AR(1): x_k = ar·x_{k-1} + noise_k — sequential in k only
    x = np.zeros((n, n_signals, window))
    for k in range(1, window):
        x[:, :, k] = ar * x[:, :, k - 1] + noise[:, :, k]

    shift_d = (phase * 3).astype(np.int64).reshape(-1)
    rolled = _roll_lastaxis(
        np.repeat(driver, n_signals, axis=0), shift_d
    ).reshape(n, n_signals, window)
    sig = gain[..., None] * rolled + x

    # masquerade: victim signal <- replayed source + offset, attack rows only
    atk = np.flatnonzero(y)
    if atk.size:
        victim = rng.integers(0, n_signals, atk.size)
        # uniform ordered pair without replacement: src = victim + U[1, S)
        src = (victim + rng.integers(1, n_signals, atk.size)) % n_signals
        shift = rng.integers(1, window // 4, atk.size)
        sig[atk, victim] = _roll_lastaxis(sig[atk, src], shift) + offset

    if raw:
        feats = sig.transpose(0, 2, 1).reshape(n, -1)  # time-major flatten
        feats = (feats - feats.mean(0)) / (feats.std(0) + 1e-9)
        return feats.astype(np.float32), y, y

    # per-signal features: mean, std, mean |Δ|, lag-1 autocorr, corr to sig 0
    mean = sig.mean(-1)
    std = sig.std(-1)
    dxm = np.abs(np.diff(sig, axis=-1)).mean(-1)
    live = std > 1e-9
    acorr = np.where(live, _corr_lastaxis(sig[..., :-1], sig[..., 1:]), 0.0)
    c0 = np.where(live, _corr_lastaxis(sig, sig[:, :1]), 0.0)
    c0[:, 0] = 1.0
    feats = np.stack([mean, std, dxm, acorr, c0], axis=-1).reshape(n, -1)
    feats = (feats - feats.mean(0)) / (feats.std(0) + 1e-9)
    return feats.astype(np.float32), y, y


def _road_like_loop(
    rng: np.random.Generator,
    n: int,
    window: int = 64,
    n_signals: int = 6,
    attack_rate: float = 0.25,
    offset: float = 0.35,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Original per-window loop — the oracle :func:`road_like` is tested
    against for statistical equivalence (kept small-n only; it is the slow
    path the vectorisation replaced)."""
    y = (rng.random(n) < attack_rate).astype(np.int32)
    feats = np.empty((n, 5 * n_signals), np.float64)
    t = np.arange(window)
    for i in range(n):
        driver = np.sin(2 * np.pi * t / window * rng.uniform(0.5, 2.0) + rng.uniform(0, 6.28))
        sig = np.empty((n_signals, window))
        phase = rng.uniform(0, 6.28, n_signals)
        gain = rng.uniform(0.5, 1.5, n_signals)
        ar = rng.uniform(0.7, 0.95, n_signals)
        for s in range(n_signals):
            noise = rng.normal(0, 0.15, window)
            x = np.zeros(window)
            for k in range(1, window):
                x[k] = ar[s] * x[k - 1] + noise[k]
            sig[s] = gain[s] * np.roll(driver, int(phase[s] * 3)) + x
        if y[i]:
            # masquerade: victim signal replaced by replayed source + offset
            victim, src = rng.choice(n_signals, 2, replace=False)
            shift = rng.integers(1, window // 4)
            sig[victim] = np.roll(sig[src], shift) + offset
        f = []
        for s in range(n_signals):
            x = sig[s]
            dx = np.abs(np.diff(x))
            ac = np.corrcoef(x[:-1], x[1:])[0, 1] if x.std() > 1e-9 else 0.0
            c0 = np.corrcoef(x, sig[0])[0, 1] if s > 0 and x.std() > 1e-9 else 1.0
            f.extend([x.mean(), x.std(), dx.mean(), ac, c0])
        feats[i] = f
    feats = (feats - feats.mean(0)) / (feats.std(0) + 1e-9)
    return feats.astype(np.float32), y, y


@dataclass
class FederatedData:
    """Per-client tabular data + metadata used by utility scores.

    ``feature_shape`` is the structured shape of one example (product ==
    ``n_features``): ``None``/``(n_features,)`` for tabular features,
    ``(window, n_signals)`` for raw CAN windows — window-native model
    specs (``models/spec.py``) unflatten with it while the whole data path
    keeps moving flat ``[*, n_features]`` arrays.
    """

    x: List[np.ndarray]
    y: List[np.ndarray]
    test_x: np.ndarray
    test_y: np.ndarray
    n_features: int
    n_classes: int
    feature_shape: Optional[Tuple[int, ...]] = None

    @property
    def n_clients(self) -> int:
        return len(self.x)

    def data_sizes(self) -> np.ndarray:
        return np.array([len(xi) for xi in self.x], np.float32)

    def label_entropy(self) -> np.ndarray:
        """Per-client normalised label entropy — the data-quality proxy."""
        out = []
        for yi in self.y:
            p = np.bincount(yi, minlength=self.n_classes).astype(np.float64)
            p = p / max(p.sum(), 1)
            h = -(p[p > 0] * np.log(p[p > 0])).sum()
            out.append(h / np.log(self.n_classes))
        return np.asarray(out, np.float32)


def dirichlet_partition(rng: np.random.Generator, labels: np.ndarray, n_clients: int,
                        alpha: float, min_per_client: int = 8) -> List[np.ndarray]:
    """Label-skewed non-IID split (standard Dirichlet protocol)."""
    n_classes = int(labels.max()) + 1
    idx_by_class = [np.where(labels == c)[0] for c in range(n_classes)]
    for idx in idx_by_class:
        rng.shuffle(idx)
    client_idx: List[List[int]] = [[] for _ in range(n_clients)]
    for c, idx in enumerate(idx_by_class):
        if len(idx) == 0:
            continue
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for ci, part in enumerate(np.split(idx, cuts)):
            client_idx[ci].extend(part.tolist())
    # guarantee a minimum shard per client
    pool = [i for cl in client_idx for i in cl]
    for ci in range(n_clients):
        while len(client_idx[ci]) < min_per_client:
            client_idx[ci].append(int(rng.choice(pool)))
    return [np.asarray(sorted(c), np.int64) for c in client_idx]


def make_federated(
    seed: int,
    dataset: str = "unsw",
    n_samples: int = 20_000,
    n_clients: int = 40,
    alpha: float = 0.5,
    test_frac: float = 0.25,
    feature_shift: float = 0.15,
    label_noise_frac: float = 0.0,
    label_noise_rate: float = 0.4,
) -> FederatedData:
    """``label_noise_frac`` of the clients get ``label_noise_rate`` of their
    labels flipped — the low-data-quality clients whose exclusion is exactly
    what the paper's utility-based selection is for (random selection keeps
    sampling them; loss-seeking ACFL actively PREFERS them).

    ``dataset="road_raw"`` is the ROAD federation over *raw* window
    matrices (``road_like(raw=True)``): x stays flat for the data path,
    ``feature_shape=(window, n_signals)`` tells window-native models how to
    unflatten."""
    rng = np.random.default_rng(seed)
    feature_shape = None
    if dataset == "unsw":
        X, y_cat, y_bin = unsw_nb15_like(rng, n_samples)
        y = y_bin  # anomaly detection = binary task (paper metric: AUC-ROC)
    elif dataset == "road":
        X, y, _ = road_like(rng, n_samples)
    elif dataset == "road_raw":
        window, n_signals = 64, 6
        X, y, _ = road_like(rng, n_samples, window=window,
                            n_signals=n_signals, raw=True)
        feature_shape = (window, n_signals)
    else:
        raise ValueError(dataset)
    n_test = int(len(X) * test_frac)
    perm = rng.permutation(len(X))
    test_i, train_i = perm[:n_test], perm[n_test:]
    parts = dirichlet_partition(rng, y[train_i], n_clients, alpha)
    noisy_clients = set(
        rng.choice(n_clients, int(round(label_noise_frac * n_clients)),
                   replace=False).tolist()
    )
    xs, ys = [], []
    for ci, pi in enumerate(parts):
        gi = train_i[pi]
        shift = rng.normal(0, feature_shift, X.shape[1]).astype(np.float32)
        xs.append(X[gi] + shift)  # per-client covariate shift
        yi = y[gi].copy()
        if ci in noisy_clients:
            flip = rng.random(len(yi)) < label_noise_rate
            yi[flip] = 1 - yi[flip]  # binary labels
        ys.append(yi)
    return FederatedData(
        x=xs, y=ys, test_x=X[test_i], test_y=y[test_i],
        n_features=X.shape[1], n_classes=2, feature_shape=feature_shape,
    )


def round_batches(rng: np.random.Generator, fed: FederatedData, local_steps: int,
                  batch: int) -> Dict[str, np.ndarray]:
    """Sample per-round batches: leaves [n_clients, local_steps, batch, ...]."""
    n = fed.n_clients
    xs = np.empty((n, local_steps, batch, fed.n_features), np.float32)
    ys = np.empty((n, local_steps, batch), np.int32)
    for ci in range(n):
        idx = rng.integers(0, len(fed.x[ci]), (local_steps, batch))
        xs[ci] = fed.x[ci][idx]
        ys[ci] = fed.y[ci][idx]
    return {"x": xs, "y": ys}


# ---------------------------------------------------------------------------
# Device-side federation (for the lax.scan engine in train/fl_driver.py)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class StackedFederation:
    """Ragged per-client shards padded to [n_clients, max_n, ...] on device.

    ``sizes`` masks the padding: sampling draws indices in [0, sizes[i]) so
    the pad rows are never read.  This is the representation that lets batch
    sampling live *inside* a lowered round loop (no host sync per round).

    Registered as a pytree so the compiled engine takes it as a runtime
    argument: one compiled program serves every federation with the same
    shapes (the engine's runner cache keys on shapes, not data).
    """

    x: jnp.ndarray        # [n_clients, max_n, d] f32
    y: jnp.ndarray        # [n_clients, max_n] i32
    sizes: jnp.ndarray    # [n_clients] i32 valid rows per client
    test_x: jnp.ndarray   # [n_test, d] f32
    test_y: jnp.ndarray   # [n_test] i32

    @property
    def n_clients(self) -> int:
        return self.x.shape[0]

    def shapes(self) -> Tuple:
        """Static fingerprint for compiled-program reuse."""
        return tuple((l.shape, str(l.dtype)) for l in
                     (self.x, self.y, self.sizes, self.test_x, self.test_y))


jax.tree_util.register_dataclass(
    StackedFederation,
    data_fields=("x", "y", "sizes", "test_x", "test_y"),
    meta_fields=(),
)


def stack_federation(fed: FederatedData) -> StackedFederation:
    """Pad the ragged client shards into one device-resident array set."""
    max_n = max(len(xi) for xi in fed.x)
    xs = np.zeros((fed.n_clients, max_n, fed.n_features), np.float32)
    ys = np.zeros((fed.n_clients, max_n), np.int32)
    for ci, (xi, yi) in enumerate(zip(fed.x, fed.y)):
        xs[ci, : len(xi)] = xi
        ys[ci, : len(yi)] = yi
    return StackedFederation(
        x=jnp.asarray(xs),
        y=jnp.asarray(ys),
        sizes=jnp.asarray(fed.data_sizes().astype(np.int32)),
        test_x=jnp.asarray(fed.test_x),
        test_y=jnp.asarray(fed.test_y),
    )


def sample_round_batches(key, stack: StackedFederation, local_steps: int,
                         batch: int) -> Dict[str, jnp.ndarray]:
    """jit-safe analogue of :func:`round_batches`: uniform with-replacement
    draws from each client's valid rows, leaves [n_clients, steps, batch, ...].
    """
    keys = jax.random.split(key, stack.n_clients)

    def per_client(k, xi, yi, size):
        idx = jax.random.randint(k, (local_steps, batch), 0, size)
        return xi[idx], yi[idx]

    xs, ys = jax.vmap(per_client)(keys, stack.x, stack.y, stack.sizes)
    return {"x": xs, "y": ys}


# ---------------------------------------------------------------------------
# Population-scale federation (ISSUE 6): lazy client shards over a shared
# sample pool, consumed by the cohort engine in train/fl_driver.py
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Population:
    """A 10^5–10^6-client federation without 10^5 materialised shards.

    :class:`StackedFederation` pads every client's samples into
    ``[n_clients, max_n, d]`` — perfect at 8–40 clients, hopeless at 100k
    (100k × 500 × 42 f32 ≈ 8.4 GB before training starts).  A Population
    stores the DISTRIBUTION structure instead:

    * ``pool_x/pool_y`` — one shared sample pool (O(pool), not O(N));
    * ``member_idx [N, m] i32`` — each client's shard as rows into the
      pool (the lazy "materialisation": 100k × 32 i32 ≈ 13 MB);
    * ``member_size [N]`` — valid prefix per client (size heterogeneity);
    * per-client covariate shift applied ON DEVICE at batch-sampling time
      from ``fold_in(shift_key, client_id)`` — zero resident bytes for
      the one per-client tensor that scales with d.

    The per-client axis (``member_idx``, ``member_size``, ``data_size``,
    ``data_quality``) is what the population engine shards over the
    ``client`` mesh axis (``models/sharding.py::population_shardings``);
    the pool and test set replicate.  Registered as a pytree so the
    compiled engine takes it as a runtime argument like a
    StackedFederation; ``shapes()`` is the runner-cache fingerprint.
    Memory accounting for all of this lives in ``core/scale.py``
    (DESIGN.md §7).
    """

    pool_x: jnp.ndarray        # [pool, d] f32 shared sample pool (train)
    pool_y: jnp.ndarray        # [pool] i32
    member_idx: jnp.ndarray    # [n_clients, m] i32 rows into the pool
    member_size: jnp.ndarray   # [n_clients] i32 valid members (<= m)
    data_size: jnp.ndarray     # [n_clients] f32 normalised shard size
    data_quality: jnp.ndarray  # [n_clients] f32 label-entropy proxy
    shift_key: jnp.ndarray     # PRNG key: per-client covariate shift seed
    test_x: jnp.ndarray        # [n_test, d] f32
    test_y: jnp.ndarray        # [n_test] i32
    feature_shift: float = 0.15
    feature_shape: Optional[Tuple[int, ...]] = None

    @property
    def n_clients(self) -> int:
        return self.member_idx.shape[0]

    @property
    def n_features(self) -> int:
        return self.pool_x.shape[1]

    @property
    def n_classes(self) -> int:
        return 2

    @property
    def members_per_client(self) -> int:
        return self.member_idx.shape[1]

    def shapes(self) -> Tuple:
        """Static fingerprint for compiled-program reuse."""
        leaves = (self.pool_x, self.pool_y, self.member_idx,
                  self.member_size, self.data_size, self.data_quality,
                  self.test_x, self.test_y)
        return (tuple((l.shape, str(l.dtype)) for l in leaves),
                self.feature_shift, self.feature_shape)


jax.tree_util.register_dataclass(
    Population,
    data_fields=("pool_x", "pool_y", "member_idx", "member_size",
                 "data_size", "data_quality", "shift_key",
                 "test_x", "test_y"),
    meta_fields=("feature_shift", "feature_shape"),
)


def make_population(
    seed: int,
    dataset: str = "unsw",
    n_clients: int = 100_000,
    pool_samples: int = 8_000,
    members_per_client: int = 32,
    alpha: float = 0.5,
    test_frac: float = 0.25,
    feature_shift: float = 0.15,
    chunk_clients: int = 16_384,
) -> Population:
    """Generate a :class:`Population` lazily: the client axis is built in
    ``chunk_clients``-sized NumPy chunks (membership draws, row shuffles,
    entropy) so peak host memory is O(chunk × m), never O(N × samples) —
    a million-client population streams through ~64 chunks.

    Non-IID structure matches :func:`make_federated` in kind: per-client
    Beta(α, α) label propensity (the binary Dirichlet) decides each
    client's attack share, membership rows are drawn from the matching
    class buckets of the pool, and the per-client covariate shift is
    deferred to on-device sampling (``sample_cohort_batches``) via
    ``fold_in(shift_key, client_id)``.
    """
    rng = np.random.default_rng(seed)
    feature_shape = None
    if dataset == "unsw":
        X, _, y = unsw_nb15_like(rng, pool_samples)
    elif dataset == "road":
        X, y, _ = road_like(rng, pool_samples)
    elif dataset == "road_raw":
        window, n_signals = 64, 6
        X, y, _ = road_like(rng, pool_samples, window=window,
                            n_signals=n_signals, raw=True)
        feature_shape = (window, n_signals)
    else:
        raise ValueError(dataset)
    n_test = int(len(X) * test_frac)
    perm = rng.permutation(len(X))
    test_i, train_i = perm[:n_test], perm[n_test:]
    Xtr, ytr = X[train_i], y[train_i]

    buckets = [np.flatnonzero(ytr == c) for c in (0, 1)]
    if any(len(b) == 0 for b in buckets):
        raise ValueError("pool has an empty class — enlarge pool_samples")

    m = int(members_per_client)
    member_size = rng.integers(max(m // 2, 1), m + 1,
                               n_clients).astype(np.int32)
    member_idx = np.empty((n_clients, m), np.int32)
    quality = np.empty((n_clients,), np.float32)
    for lo in range(0, n_clients, chunk_clients):
        hi = min(lo + chunk_clients, n_clients)
        c = hi - lo
        p1 = rng.beta(alpha, alpha, c)                    # binary Dirichlet
        n1 = rng.binomial(m, p1)
        cols = np.arange(m)[None, :]
        is1 = cols < n1[:, None]                          # [c, m] class plan
        rows = np.where(
            is1,
            buckets[1][rng.integers(0, len(buckets[1]), (c, m))],
            buckets[0][rng.integers(0, len(buckets[0]), (c, m))],
        )
        # shuffle within each row so the member_size prefix stays a fair
        # mix of the client's classes
        order = rng.random((c, m)).argsort(axis=1)
        rows = np.take_along_axis(rows, order, axis=1)
        member_idx[lo:hi] = rows
        lab = ytr[rows]                                   # [c, m]
        valid = cols < member_size[lo:hi][:, None]
        p = (lab * valid).sum(1) / np.maximum(member_size[lo:hi], 1)
        p = np.clip(p, 1e-9, 1 - 1e-9)
        quality[lo:hi] = -(p * np.log(p) + (1 - p) * np.log(1 - p)) / np.log(2)

    sizes = member_size.astype(np.float32)
    return Population(
        pool_x=jnp.asarray(Xtr),
        pool_y=jnp.asarray(ytr.astype(np.int32)),
        member_idx=jnp.asarray(member_idx),
        member_size=jnp.asarray(member_size),
        data_size=jnp.asarray(sizes / sizes.mean()),
        data_quality=jnp.asarray(quality),
        shift_key=jax.random.key(np.uint32(seed) ^ np.uint32(0x5CA1E)),
        test_x=jnp.asarray(X[test_i]),
        test_y=jnp.asarray(y[test_i].astype(np.int32)),
        feature_shift=float(feature_shift),
        feature_shape=feature_shape,
    )


def sample_cohort_batches(key, pop: Population, cohort_idx,
                          local_steps: int, batch: int) -> Dict[str, jnp.ndarray]:
    """The cohort gather: batches for the SELECTED clients only, leaves
    ``[k_max, local_steps, batch, ...]`` — per-round data traffic is
    O(k_max · steps · batch · d), independent of the population size
    (that independence is the population engine's sublinear-wall claim,
    gated in benchmarks/bench_scale.py).

    Each cohort slot gathers its membership row, draws uniform
    with-replacement sample indices from its valid prefix, gathers those
    pool rows, and adds the client's covariate shift — generated on the
    fly from ``fold_in(shift_key, client_id)``, so the shift is a stable
    per-client property that never occupies [N, d] resident memory.
    """
    k = cohort_idx.shape[0]
    keys = jax.random.split(key, k)
    d = pop.pool_x.shape[1]
    mem = pop.member_idx[cohort_idx]
    msize = pop.member_size[cohort_idx]

    def per_slot(kk, mem_i, size_i, ci):
        j = jax.random.randint(kk, (local_steps, batch), 0,
                               jnp.maximum(size_i, 1))
        rows = mem_i[j]
        shift = pop.feature_shift * jax.random.normal(
            jax.random.fold_in(pop.shift_key, ci), (d,))
        return pop.pool_x[rows] + shift, pop.pool_y[rows]

    xs, ys = jax.vmap(per_slot)(keys, mem, msize, cohort_idx)
    return {"x": xs, "y": ys}
