"""Synthetic language-model token pipeline (for the assigned architectures).

Offline container -> no corpus; we synthesise token streams with enough
structure to make loss curves meaningful (Zipfian unigram + Markov bigram
mixture), partitioned per FL client with client-specific bigram tables so
the federation is genuinely non-IID at the sequence level.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np


class ZipfMarkovStream:
    """Per-client token source: mixture of a shared Zipf unigram and a
    client-specific sparse bigram transition."""

    def __init__(self, vocab: int, seed: int, bigram_strength: float = 0.5,
                 n_hot: int = 8):
        self.vocab = vocab
        self.rng = np.random.default_rng(seed)
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        self.unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        self.bigram_strength = bigram_strength
        # sparse per-state successor sets (memory-light for 200k vocabs)
        self.n_hot = n_hot
        self.succ_seed = int(self.rng.integers(0, 2**31))

    def _successors(self, tok: np.ndarray) -> np.ndarray:
        # hash-derived deterministic successor set per token
        h = (tok.astype(np.int64) * 2654435761 + self.succ_seed) % (2**31)
        return (h[:, None] * np.arange(1, self.n_hot + 1)) % self.vocab

    def sample(self, batch: int, seq: int) -> np.ndarray:
        out = np.empty((batch, seq), np.int64)
        out[:, 0] = self.rng.choice(self.vocab, size=batch, p=self.unigram)
        for t in range(1, seq):
            succ = self._successors(out[:, t - 1])
            use_bigram = self.rng.random(batch) < self.bigram_strength
            pick = succ[np.arange(batch), self.rng.integers(0, self.n_hot, batch)]
            uni = self.rng.choice(self.vocab, size=batch, p=self.unigram)
            out[:, t] = np.where(use_bigram, pick, uni)
        return out.astype(np.int32)


def lm_round_batches(
    vocab: int,
    n_clients: int,
    local_steps: int,
    batch: int,
    seq: int,
    seed: int,
    round_idx: int = 0,
) -> Dict[str, np.ndarray]:
    """[n_clients, local_steps, batch, seq] token/label arrays for a round."""
    toks = np.empty((n_clients, local_steps, batch, seq + 1), np.int32)
    for ci in range(n_clients):
        stream = ZipfMarkovStream(vocab, seed * 1000 + ci)
        toks[ci] = stream.sample(local_steps * batch, seq + 1).reshape(
            local_steps, batch, seq + 1
        )
    return {"tokens": toks[..., :-1], "labels": toks[..., 1:].copy()}


def lm_eval_batch(vocab: int, batch: int, seq: int, seed: int) -> Dict[str, np.ndarray]:
    stream = ZipfMarkovStream(vocab, seed)
    t = stream.sample(batch, seq + 1)
    return {"tokens": t[:, :-1], "labels": t[:, 1:].copy()}
