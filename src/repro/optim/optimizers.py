"""Hand-rolled optimizers (no optax in this environment).

Each optimizer is a pair of pure functions bundled in :class:`Optimizer`:
    init(params) -> state
    update(grads, state, params) -> (new_params, new_state)
Used both for client-local SGD and for server-side FedOpt variants
(FedAvg ≡ server SGD(1.0) on the aggregated pseudo-gradient, FedAvgM,
FedAdam — Reddi et al., "Adaptive Federated Optimization").
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable
    name: str


def _tmap(f, *trees):
    return jax.tree.map(f, *trees)


def sgd(lr: float, momentum: float = 0.0, nesterov: bool = False,
        weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return _tmap(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(grads, state, params):
        if weight_decay:
            grads = _tmap(lambda g, p: g + weight_decay * p.astype(g.dtype), grads, params)
        if momentum == 0.0:
            new_p = _tmap(lambda p, g: (p - lr * g.astype(jnp.float32)).astype(p.dtype),
                          params, grads)
            return new_p, ()
        new_m = _tmap(lambda m, g: momentum * m + g.astype(jnp.float32), state, grads)
        if nesterov:
            step = _tmap(lambda m, g: momentum * m + g.astype(jnp.float32), new_m, grads)
        else:
            step = new_m
        new_p = _tmap(lambda p, s: (p - lr * s).astype(p.dtype), params, step)
        return new_p, new_m

    return Optimizer(init, update, f"sgd(lr={lr},m={momentum})")


class AdamState(NamedTuple):
    mu: Any
    nu: Any
    count: jnp.ndarray


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = _tmap(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return AdamState(z, _tmap(jnp.zeros_like, z), jnp.zeros((), jnp.int32))

    def update(grads, state, params):
        c = state.count + 1
        gf = _tmap(lambda g: g.astype(jnp.float32), grads)
        mu = _tmap(lambda m, g: b1 * m + (1 - b1) * g, state.mu, gf)
        nu = _tmap(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, gf)
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def step(p, m, v):
            upd = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                upd = upd + weight_decay * p.astype(jnp.float32)
            return (p - lr * upd).astype(p.dtype)

        return _tmap(step, params, mu, nu), AdamState(mu, nu, c)

    return Optimizer(init, update, f"adam(lr={lr})")


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    return adam(lr, b1, b2, eps, weight_decay)._replace(name=f"adamw(lr={lr})")


# ---------------------------------------------------------------------------
# Server-side (FedOpt family) — operate on the aggregated update Δ as a
# pseudo-gradient: w <- w + server_opt(Δ).
# ---------------------------------------------------------------------------


def make_server_optimizer(name: str, lr: float) -> Optimizer:
    if name == "sgd":  # FedAvg when lr == 1.0
        base = sgd(lr)
    elif name == "fedavgm":
        base = sgd(lr, momentum=0.9)
    elif name == "fedadam":
        base = adam(lr, b1=0.9, b2=0.99, eps=1e-3)
    else:
        raise ValueError(name)

    # server consumes a pseudo-gradient = -Δ (so that w <- w + lr·Δ for sgd)
    def update(agg_delta, state, params):
        neg = jax.tree.map(lambda d: -d, agg_delta)
        return base.update(neg, state, params)

    return Optimizer(base.init, update, f"server_{base.name}")
