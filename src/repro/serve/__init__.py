"""Streaming anomaly-scoring engine: serve federated detectors at traffic
rate (ISSUE 7, ARCHITECTURE.md §Serving).

Layout::

    batching.py   static batch buckets: plan/pad/accumulate
    feed.py       double-buffered host→device upload prefetch
    engine.py     compiled ServeEngine + self-describing checkpoints
    cli.py        python -m repro.serve — train-if-missing, then serve

Quick start::

    from repro.serve import ServeEngine, save_serving_checkpoint
    save_serving_checkpoint("ckpt/serve_mlp", params, "mlp", meta)
    eng = ServeEngine.from_checkpoint("ckpt/serve_mlp")
    scores = eng.score(windows)              # [n] anomaly probabilities
"""
from repro.serve.batching import (DEFAULT_BUCKETS, Bucketer, batches_of,
                                  bucket_for, pad_to, plan_chunks)
from repro.serve.engine import (SERVE_STATS, ServeEngine, StreamReport,
                                save_serving_checkpoint)
from repro.serve.feed import device_feed

__all__ = [
    "DEFAULT_BUCKETS", "Bucketer", "batches_of", "bucket_for", "pad_to",
    "plan_chunks", "SERVE_STATS", "ServeEngine", "StreamReport",
    "save_serving_checkpoint", "device_feed",
]
