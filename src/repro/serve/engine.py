"""Compiled streaming anomaly-scoring engine (ISSUE 7, ARCHITECTURE.md
§Serving).

Training exists to put a detector in front of live traffic; this is the
deployment half: load a federated checkpoint, resolve the registered
:class:`~repro.models.spec.ModelSpec`, and score a continuous stream of
CAN/NetFlow windows at traffic rate.  Three pieces of perf machinery:

* **padded bucket batching** (``serve/batching.py``) — incoming windows are
  bucketed into a small set of static batch shapes, so each (model, bucket)
  pair compiles exactly once.  ``_get_scorer`` mirrors the training
  engine's ``_get_runner``: a module-level cache keyed on (model name,
  DataMeta, bucket, route) with ``SERVE_STATS`` miss/hit counters that the
  bench asserts on.
* **double-buffered host→device feed** (``serve/feed.py``) — batch N+1's
  ``device_put`` is issued before batch N is dispatched, and the engine
  blocks on batch N−1 only after dispatching N, so upload, dispatch and
  compute overlap at pipeline depth one.  Off-CPU the scorer donates its
  input buffer (it is rebuilt per batch anyway).
* **kernel routing** — sequence detectors carry per-route logits
  (``ModelSpec.route_variants``): the ``"kernel"`` route runs the Pallas
  flash_attention/flash_decode kernels (compiled on TPU), ``"ref"`` the
  pure-jnp ``kernels/ref`` oracles; ``route=None`` resolves by backend
  exactly like the DP clip+noise aggregation path.  On every route the
  served scores are bitwise equal to the same-route
  ``ModelSpec.predict_proba`` on the same windows (padding rows masked) —
  tests/test_serve.py pins it.

Per-client personalization: an optional stacked pytree of FedL2P-style
personalized parameters (``train/fl_driver.export_personalized``) rides the
same checkpoint; ``client=i`` scores with client i's fine-tuned detector at
zero recompile cost (parameters are runtime arguments of the cached
scorer).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt_lib
from repro.kernels.ops import default_route
from repro.models.spec import DataMeta, ModelSpec, get_model_spec
from repro.obs import stats as obs_stats
from repro.obs import trace as obs_trace
from repro.serve import batching, feed

# Compiled per-(model, DataMeta, bucket, route) scorers.  Keyed on the spec
# NAME (registry builders are deterministic in the DataMeta), so two engines
# serving the same architecture share one program — the single-compile
# property benchmarks/bench_serve.py asserts via SERVE_STATS.  The counters
# are a view of the unified registry ("serve" namespace, repro.obs.stats).
_SCORER_CACHE: Dict = {}
SERVE_STATS = obs_stats.STATS.counters("serve", misses=0, hits=0)


def _get_scorer(spec: ModelSpec, meta: DataMeta, bucket: int,
                route: str) -> Callable:
    """Compiled ``scorer(params, x[bucket, d]) -> scores[bucket]`` (the
    class-1 anomaly probability).  The input buffer is donated off-CPU —
    the feed rebuilds it per batch, so XLA may alias it into the
    activations instead of holding both live."""
    cache_key = (spec.name, meta, int(bucket), route)
    scorer = _SCORER_CACHE.get(cache_key)
    if scorer is None:
        SERVE_STATS["misses"] += 1
        obs_trace.event("compile.scorer_miss", model=spec.name,
                        bucket=int(bucket), route=route,
                        cache_size=len(_SCORER_CACHE))
        logits_fn = spec.logits_routed(route)

        def score(params, x):
            return jax.nn.softmax(logits_fn(params, x), axis=-1)[:, 1]

        donate = () if jax.default_backend() == "cpu" else (1,)
        scorer = jax.jit(score, donate_argnums=donate)
        _SCORER_CACHE[cache_key] = scorer
    else:
        SERVE_STATS["hits"] += 1
    return scorer


@dataclass
class StreamReport:
    """Scores plus the first-class serving metrics (windows/sec, p50/p99
    per-window latency).  A window's latency is its batch's wall — every
    window in a batch completes when the batch does."""

    scores: np.ndarray
    n_windows: int
    n_batches: int
    wall_s: float
    batch_walls_s: List[float] = field(default_factory=list)
    batch_sizes: List[int] = field(default_factory=list)

    @property
    def windows_per_sec(self) -> float:
        return self.n_windows / self.wall_s if self.wall_s else float("inf")

    def latency_percentile(self, q: float) -> float:
        """Per-window latency percentile: batch walls weighted by the
        number of valid windows each batch carried."""
        per_window = np.repeat(np.asarray(self.batch_walls_s),
                               np.asarray(self.batch_sizes))
        return float(np.percentile(per_window, q))

    @property
    def p50_s(self) -> float:
        return self.latency_percentile(50.0)

    @property
    def p99_s(self) -> float:
        return self.latency_percentile(99.0)


class ServeEngine:
    """Streaming scorer for one trained detector (+ optional personalized
    per-client parameters).

    ``spec``/``meta``/``params`` usually come from
    :meth:`from_checkpoint`; ``buckets`` are the static batch shapes
    (``serve/batching.py``); ``route`` picks the score-path kernels for
    sequence detectors (``None`` = by backend, like DP clip+noise).
    """

    def __init__(self, spec: ModelSpec, meta: DataMeta, params,
                 *, buckets: Sequence[int] = batching.DEFAULT_BUCKETS,
                 route: Optional[str] = None, heads=None):
        self.spec = spec
        self.meta = meta
        self.params = params
        self.buckets = batching.normalize_buckets(buckets)
        self.route = route or default_route()
        self.heads = heads
        # resolve eagerly so an invalid route fails at construction
        spec.logits_routed(self.route)

    # -- parameters -------------------------------------------------------

    def params_for(self, client: Optional[int]):
        """Global params, or client ``i``'s personalized tree (a leading-axis
        slice of the stacked heads — no recompile: same leaf shapes)."""
        if client is None:
            return self.params
        if self.heads is None:
            raise ValueError(
                "engine has no personalized heads; export them with "
                "train/fl_driver.export_personalized and pass heads=... "
                "(or save_serving_checkpoint(..., heads=...))")
        return jax.tree.map(lambda h: h[int(client)], self.heads)

    @property
    def n_personalized(self) -> int:
        if self.heads is None:
            return 0
        return int(jax.tree.leaves(self.heads)[0].shape[0])

    # -- scoring ----------------------------------------------------------

    def warmup(self):
        """Compile every (model, bucket) program outside the serving path."""
        d = int(np.prod(self.meta.feature_shape))
        for b in self.buckets:
            scorer = _get_scorer(self.spec, self.meta, b, self.route)
            jax.block_until_ready(
                scorer(self.params, jnp.zeros((b, d), jnp.float32)))

    def score(self, windows: np.ndarray,
              client: Optional[int] = None) -> np.ndarray:
        """Score an [n, d] array of flat windows in bucket-shaped batches;
        returns [n] anomaly scores in input order (padding rows dropped)."""
        report = self.score_stream([np.asarray(windows)], client=client)
        return report.scores

    def score_stream(self, stream: Iterable[np.ndarray],
                     client: Optional[int] = None,
                     sharding=None) -> StreamReport:
        """Drain a stream of [m, d] window chunks through the pipelined
        scorer (bucket batching → double-buffered feed → dispatch-ahead
        scoring) and collect scores + timing."""
        params = self.params_for(client)
        batches = batching.batches_of(stream, self.buckets)
        with obs_trace.span("serve.score_stream", model=self.spec.name,
                            route=self.route):
            t0 = time.perf_counter()
            t_prev = t0
            pending: Optional[Tuple[jax.Array, int]] = None
            scores: List[np.ndarray] = []
            walls: List[float] = []
            sizes: List[int] = []

            def _drain(entry, t_prev):
                res, n_valid = entry
                res.block_until_ready()
                t_now = time.perf_counter()
                scores.append(np.asarray(res)[:n_valid])
                walls.append(t_now - t_prev)
                sizes.append(n_valid)
                return t_now

            for xb, n_valid in feed.device_feed(batches, sharding):
                with obs_trace.span("serve.dispatch",
                                    bucket=int(xb.shape[0])):
                    scorer = _get_scorer(self.spec, self.meta, xb.shape[0],
                                         self.route)
                    res = scorer(params, xb)    # async dispatch of batch N
                if pending is not None:
                    # block on batch N-1 only
                    t_prev = _drain(pending, t_prev)
                pending = (res, n_valid)
            if pending is not None:
                _drain(pending, t_prev)

            wall = time.perf_counter() - t0
        out = (np.concatenate(scores) if scores
               else np.zeros((0,), np.float32))
        return StreamReport(scores=out, n_windows=int(out.shape[0]),
                            n_batches=len(walls), wall_s=wall,
                            batch_walls_s=walls, batch_sizes=sizes)

    def score_naive(self, windows: np.ndarray,
                    client: Optional[int] = None) -> StreamReport:
        """The baseline this engine exists to beat: one synchronous
        batch-1 ``predict_proba`` dispatch per window (no batching, no
        feed overlap).  Used by benchmarks/bench_serve.py's ≥5× gate."""
        params = self.params_for(client)
        scorer = _get_scorer(self.spec, self.meta, 1, self.route)
        windows = np.asarray(windows)
        t0 = time.perf_counter()
        t_prev = t0
        scores, walls = [], []
        for i in range(windows.shape[0]):
            res = scorer(params, jnp.asarray(windows[i:i + 1]))
            res.block_until_ready()
            t_now = time.perf_counter()
            scores.append(np.asarray(res))
            walls.append(t_now - t_prev)
            t_prev = t_now
        wall = time.perf_counter() - t0
        out = (np.concatenate(scores) if scores
               else np.zeros((0,), np.float32))
        return StreamReport(scores=out, n_windows=int(out.shape[0]),
                            n_batches=len(walls), wall_s=wall,
                            batch_walls_s=walls, batch_sizes=[1] * len(walls))

    # -- checkpoints ------------------------------------------------------

    @classmethod
    def from_checkpoint(cls, path: str, *,
                        buckets: Sequence[int] = batching.DEFAULT_BUCKETS,
                        route: Optional[str] = None) -> "ServeEngine":
        """Rebuild an engine from a self-describing serving checkpoint
        (``save_serving_checkpoint``): the manifest carries the model name
        and DataMeta, so no config object is needed at load time."""
        manifest = ckpt_lib.load_manifest(path)
        info = (manifest.get("metadata") or {}).get("serve")
        if not info:
            raise ValueError(
                f"{path} is not a serving checkpoint (no 'serve' metadata); "
                "write it with serve.engine.save_serving_checkpoint")
        meta = DataMeta(n_features=int(info["meta"]["n_features"]),
                        n_classes=int(info["meta"]["n_classes"]),
                        hidden=int(info["meta"]["hidden"]),
                        feature_shape=tuple(info["meta"]["feature_shape"]))
        spec = get_model_spec(info["model"], meta)
        template: Dict[str, Any] = {"params": spec.init(jax.random.key(0))}
        n_heads = int(info.get("n_personalized", 0))
        if n_heads:
            template["heads"] = jax.tree.map(
                lambda x: jnp.zeros((n_heads,) + x.shape, x.dtype),
                template["params"])
        tree = ckpt_lib.restore_pytree(path, template)
        return cls(spec, meta, tree["params"], buckets=buckets, route=route,
                   heads=tree.get("heads"))


def save_serving_checkpoint(path: str, params, model: str, meta: DataMeta,
                            heads=None, extra_metadata: Optional[dict] = None
                            ) -> str:
    """Write a self-describing serving checkpoint: the final-params pytree
    (plus optional stacked personalized heads) with the model name and
    :class:`DataMeta` in the manifest, so ``ServeEngine.from_checkpoint``
    needs only the path.  Integrity contract: restore is bitwise
    (tests/test_serve.py round-trips every registered spec and pins
    ``predict_proba`` equality)."""
    tree: Dict[str, Any] = {"params": params}
    info = {"model": model, "meta": meta._asdict(),
            "n_personalized": (0 if heads is None else
                               int(jax.tree.leaves(heads)[0].shape[0]))}
    if heads is not None:
        tree["heads"] = heads
    return ckpt_lib.save_pytree(
        path, tree, {"serve": {**info, **(extra_metadata or {})}})
