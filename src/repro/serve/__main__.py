from repro.serve.cli import main

main()
