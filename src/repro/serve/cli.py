"""CLI: stream-score a test federation through the serving engine.

PYTHONPATH=src python -m repro.serve \
    --model mlp --dataset unsw --ckpt ckpt/serve_mlp \
    [--buckets 16,128] [--route kernel|ref] [--rounds 30] [--chunk 37]

Train-if-missing: when ``--ckpt`` does not exist yet, a short federated run
(``run_fl(..., return_params=True)``) trains the detector and
``save_serving_checkpoint`` persists it; subsequent invocations go straight
from checkpoint to traffic.  The stream is the federation's test windows
replayed in ``--chunk``-sized arrival bursts — the serving engine rebatches
them into its static buckets (ARCHITECTURE.md §Serving).
"""
from __future__ import annotations

import argparse
import contextlib
import os

import numpy as np

from repro.configs.base import FLConfig
from repro.data.synthetic import make_federated
from repro.models.spec import meta_for
from repro.obs import profile_trace
from repro.serve.engine import ServeEngine, save_serving_checkpoint
from repro.train.fl_driver import run_fl


def _train_checkpoint(args) -> str:
    fed = make_federated(args.seed, args.dataset, n_samples=args.samples,
                         n_clients=args.clients)
    fl = FLConfig(n_clients=args.clients,
                  clients_per_round=max(4, args.clients // 5),
                  rounds=args.rounds, local_epochs=2, local_batch=32,
                  local_lr=0.08, dp_enabled=False, fault_tolerance=False,
                  model=args.model)
    res = run_fl(fed, fl, "random", seed=args.seed, rounds=args.rounds,
                 eval_every=max(args.rounds // 4, 1), dataset=args.dataset,
                 hidden=args.hidden, return_params=True)
    print(f"trained {args.model}/{args.dataset}: acc={res.accuracy*100:.1f}% "
          f"auc={res.auc:.3f}")
    return save_serving_checkpoint(args.ckpt, res.params, args.model,
                                   meta_for(fed, hidden=args.hidden))


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--model", default="mlp")
    ap.add_argument("--dataset", choices=["unsw", "road", "road_raw"],
                    default="unsw")
    ap.add_argument("--ckpt", default=None,
                    help="serving checkpoint path (default ckpt/serve_<model>_<dataset>)")
    ap.add_argument("--buckets", default="16,128",
                    help="comma-separated static batch buckets")
    ap.add_argument("--route", choices=["kernel", "ref"], default=None,
                    help="score-path kernels for sequence models (default: by backend)")
    ap.add_argument("--rounds", type=int, default=30,
                    help="training rounds when the checkpoint is missing")
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--samples", type=int, default=6_000)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--chunk", type=int, default=37,
                    help="windows per simulated arrival burst")
    ap.add_argument("--repeat", type=int, default=4,
                    help="replays of the test set through the stream")
    ap.add_argument("--client", type=int, default=None,
                    help="score with this client's personalized params")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--profile", nargs="?", const="profiles/serve",
                    default=None, metavar="LOGDIR",
                    help="dump a TensorBoard-loadable jax.profiler trace "
                         "of the scoring stream to LOGDIR "
                         "(default profiles/serve)")
    args = ap.parse_args(argv)
    if args.ckpt is None:
        args.ckpt = f"ckpt/serve_{args.model}_{args.dataset}"

    npz = args.ckpt if args.ckpt.endswith(".npz") else args.ckpt + ".npz"
    if not os.path.exists(npz):
        print(f"no checkpoint at {npz}; training one")
        _train_checkpoint(args)

    buckets = tuple(int(b) for b in args.buckets.split(","))
    eng = ServeEngine.from_checkpoint(args.ckpt, buckets=buckets,
                                      route=args.route)
    eng.warmup()

    fed = make_federated(args.seed, args.dataset, n_samples=args.samples,
                         n_clients=args.clients)
    windows = np.asarray(fed.test_x, np.float32)

    def stream():
        for _ in range(args.repeat):
            for i in range(0, windows.shape[0], args.chunk):
                yield windows[i:i + args.chunk]

    prof = (profile_trace(args.profile) if args.profile
            else contextlib.nullcontext())
    with prof:
        report = eng.score_stream(stream(), client=args.client)
    if args.profile:
        print(f"profiler trace written to {args.profile} "
              f"(load with: tensorboard --logdir {args.profile})")
    print(f"model={eng.spec.name} route={eng.route} buckets={eng.buckets} "
          f"ckpt={npz}")
    print(f"scored {report.n_windows} windows in {report.n_batches} batches: "
          f"{report.windows_per_sec:,.0f} windows/s  "
          f"p50={report.p50_s*1e3:.3f}ms  p99={report.p99_s*1e3:.3f}ms")
    print(f"anomaly-score mean={report.scores.mean():.4f} "
          f"min={report.scores.min():.4f} max={report.scores.max():.4f}")
    return report


if __name__ == "__main__":
    main()
