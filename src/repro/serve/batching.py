"""Padded dynamic batching for the streaming scoring engine (ISSUE 7).

A serving process sees windows arrive in arbitrary-sized chunks (per-frame
CAN captures, NetFlow export batches); a compiled scorer needs STATIC batch
shapes or it recompiles per request size.  The classic fix is a small set of
**batch buckets**: every incoming chunk is split into full max-bucket
batches plus one padded remainder batch, so each (model, bucket) pair
compiles exactly once (``engine.SERVE_STATS`` counts misses, mirroring the
training engine's ``RUNNER_STATS``) and steady-state traffic runs at the
largest bucket with zero padding waste.

Padding is semantically free on the score path: every registered detector
computes row-wise over the batch axis (matmul rows, per-window convs and
scans), so the padded rows change no bit of the valid rows —
tests/test_serve.py pins serving output bitwise against the unbatched
``ModelSpec.predict_proba`` reference.
"""
from __future__ import annotations

from typing import Iterable, List, Sequence, Tuple

import numpy as np

# Two buckets cover the latency/throughput trade well: a small one so a
# trickle of windows is not padded 16x, a large one for steady-state rate.
DEFAULT_BUCKETS = (16, 128)


def normalize_buckets(buckets: Sequence[int]) -> Tuple[int, ...]:
    out = tuple(sorted({int(b) for b in buckets}))
    if not out or out[0] <= 0:
        raise ValueError(f"buckets must be positive ints, got {buckets!r}")
    return out


def bucket_for(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket that fits ``n`` windows (``n`` ≤ max bucket)."""
    bs = normalize_buckets(buckets)
    for b in bs:
        if b >= n:
            return b
    raise ValueError(f"{n} windows exceed the largest bucket {bs[-1]}; "
                     "split with plan_chunks first")


def plan_chunks(n: int, buckets: Sequence[int]) -> List[int]:
    """Greedy split of ``n`` windows into bucket-sized batches: full
    max-bucket batches while they fit, then one bucket covering the
    remainder.  ``sum(chunks) >= n`` and every chunk is a bucket."""
    bs = normalize_buckets(buckets)
    out: List[int] = []
    while n >= bs[-1]:
        out.append(bs[-1])
        n -= bs[-1]
    if n > 0:
        out.append(bucket_for(n, bs))
    return out


def pad_to(x: np.ndarray, bucket: int) -> Tuple[np.ndarray, int]:
    """Zero-pad ``x`` [n, d] up to [bucket, d]; returns (padded, n_valid)."""
    n = x.shape[0]
    if n > bucket:
        raise ValueError(f"{n} rows do not fit bucket {bucket}")
    if n == bucket:
        return x, n
    padded = np.zeros((bucket,) + x.shape[1:], x.dtype)
    padded[:n] = x
    return padded, n


class Bucketer:
    """Accumulate stream chunks, emit bucket-shaped batches.

    ``add`` emits zero-copy full max-bucket batches as soon as enough
    windows are queued; ``flush`` drains the remainder as padded batches.
    Emission order preserves arrival order, so concatenating the valid rows
    of every emitted batch reproduces the input stream exactly.
    """

    def __init__(self, buckets: Sequence[int] = DEFAULT_BUCKETS):
        self.buckets = normalize_buckets(buckets)
        self._pending: List[np.ndarray] = []
        self._n = 0

    @property
    def pending(self) -> int:
        return self._n

    def add(self, windows: np.ndarray) -> List[Tuple[np.ndarray, int]]:
        windows = np.asarray(windows)
        if windows.ndim == 1:
            windows = windows[None]
        self._pending.append(windows)
        self._n += windows.shape[0]
        out: List[Tuple[np.ndarray, int]] = []
        big = self.buckets[-1]
        if self._n >= big:
            buf = np.concatenate(self._pending, axis=0)
            while buf.shape[0] >= big:
                out.append((buf[:big], big))
                buf = buf[big:]
            self._pending = [buf] if buf.shape[0] else []
            self._n = buf.shape[0]
        return out

    def flush(self) -> List[Tuple[np.ndarray, int]]:
        if not self._n:
            return []
        buf = np.concatenate(self._pending, axis=0)
        self._pending, self._n = [], 0
        out = []
        for chunk in plan_chunks(buf.shape[0], self.buckets):
            take = min(chunk, buf.shape[0])
            out.append(pad_to(buf[:take], chunk))
            buf = buf[take:]
        return out


def batches_of(stream: Iterable[np.ndarray],
               buckets: Sequence[int] = DEFAULT_BUCKETS):
    """Generator: stream of [m, d] chunks → bucket-shaped (batch, n_valid)
    pairs, flushing the tail when the stream ends."""
    bk = Bucketer(buckets)
    for chunk in stream:
        yield from bk.add(chunk)
    yield from bk.flush()
