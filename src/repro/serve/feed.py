"""Double-buffered host→device feed for the streaming scorer (ISSUE 7).

``jax.device_put`` is asynchronous: it returns immediately with a device
array whose transfer completes in the background.  The feed exploits that
by staying exactly ONE batch ahead of the consumer — when batch N is
yielded, batch N+1's upload has already been issued, so the device never
stalls on host-side staging between dispatches (the same overlap trick as
the training engine's donated per-lane inputs, ARCHITECTURE.md §Serving).

The consumer side of the pipeline lives in ``engine.ServeEngine``: it
dispatches the scorer on batch N, and only THEN blocks on batch N−1's
result — dispatch, upload and compute of adjacent batches all overlap at
pipeline depth one.
"""
from __future__ import annotations

from typing import Iterable, Iterator, Optional, Tuple

import jax
import numpy as np


def device_feed(batches: Iterable[Tuple[np.ndarray, int]],
                sharding: Optional[jax.sharding.Sharding] = None,
                ) -> Iterator[Tuple[jax.Array, int]]:
    """(host_batch, n_valid) stream → (device_batch, n_valid) stream with
    one batch of upload prefetch.

    The generator issues ``device_put`` for batch N+1 *before* yielding
    batch N; by the time the consumer's dispatch of N returns, N+1 is
    already in flight.  ``sharding`` optionally pins the placement (a
    replicated or batch-sharded NamedSharding on multi-device serving).
    """
    it = iter(batches)
    try:
        x, n = next(it)
    except StopIteration:
        return
    cur = (jax.device_put(x, sharding), n)
    for x, n in it:
        nxt = (jax.device_put(x, sharding), n)   # async: upload starts now
        yield cur
        cur = nxt
    yield cur
