"""Shared nonparametric statistics (benchmarks AND examples import this).

The Mann-Whitney U comparison the paper uses for Table III used to be
duplicated verbatim in ``benchmarks/bench_table3.py`` and
``examples/anomaly_fl.py``; it lives here once now, and the fault-frontier
robustness gate (``benchmarks/bench_fault.py``) reuses it.
"""
from __future__ import annotations

from typing import Sequence, Tuple


def mannwhitney_greater(a: Sequence[float], b: Sequence[float],
                        alpha: float = 0.05) -> Tuple[float, float, bool]:
    """One-sided Mann-Whitney U test that ``a``'s distribution is
    stochastically greater than ``b``'s.

    Returns ``(U, p, significant)`` with significance at ``alpha``.
    scipy is imported lazily so ``repro`` stays importable on minimal
    installs that only run the engine.
    """
    from scipy import stats

    u, p = stats.mannwhitneyu(list(a), list(b), alternative="greater")
    return float(u), float(p), bool(p < alpha)
