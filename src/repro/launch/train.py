"""CLI: federated LM training on an assigned architecture (host scale).

Runs real FL rounds (Algorithm 1 — selection + DP + fault tolerance) over a
REDUCED variant of any assigned architecture on the local devices, proving
the whole train path executes, not just lowers.  The full-size configs are
exercised by ``repro.launch.dryrun`` on the 512-chip placeholder meshes.

PYTHONPATH=src python -m repro.launch.train --arch granite_3_8b --rounds 3 \
    [--full] [--plan client_serial] [--seq 64] [--batch 2]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, FLConfig, get_arch
from repro.core import rounds as rounds_lib
from repro.data.tokens import lm_eval_batch, lm_round_batches
from repro.models.model import build


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="granite_3_8b")
    ap.add_argument("--full", action="store_true",
                    help="use the full published config (needs a real pod!)")
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--clients-per-step", type=int, default=2)
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.005)
    ap.add_argument("--dp", action="store_true",
                    help="enable DP noise (off by default here: per-element "
                         "noise swamps reduced smoke models; the calibrated "
                         "DP experiments live in fl_train/benchmarks)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=not args.full)
    model = build(cfg)
    print(f"== {cfg.name} ({cfg.param_count()/1e6:.1f}M params, "
          f"family={cfg.family}) ==")

    fl = FLConfig(
        n_clients=args.clients, clients_per_round=args.clients_per_step,
        local_lr=args.lr, dp_enabled=args.dp, dp_mode="clipped",
        dp_epsilon=50.0, dp_clip=10.0, failure_prob=0.05,
        serial_clients_in_step=args.clients_per_step,
        local_steps_in_step=args.local_steps,
    )
    params = model.init(jax.random.key(args.seed))
    state = rounds_lib.init_round_state(params, fl, jax.random.key(args.seed + 1),
                                        n_clients=args.clients)
    loss_fn = lambda p, b: model.loss(p, b, remat="none")
    step = jax.jit(rounds_lib.make_serial_round(loss_fn, fl, args.clients))

    eval_b = _eval_batch(model, cfg, args.batch, args.seq, args.seed)
    ev = jax.jit(lambda p: model.loss(p, eval_b, remat="none"))
    print(f"  initial eval loss: {float(ev(state.params)):.4f}")

    for r in range(args.rounds):
        data = _round_batches(model, cfg, fl, args, seed=args.seed * 100 + r)
        t0 = time.time()
        state, m = step(state, data)
        jax.block_until_ready(m.global_loss)
        print(f"  round {r}: local_loss={float(m.global_loss):.4f} "
              f"K={float(m.k_effective):.0f} failures={int(m.failed.sum())} "
              f"({time.time()-t0:.1f}s)")
    print(f"  final eval loss: {float(ev(state.params)):.4f}")


def _with_frontend(model, cfg, batch_dict, b):
    if cfg.enc_layers > 0 or (cfg.frontend != "none" and cfg.frontend_tokens):
        n = cfg.enc_seq if cfg.enc_layers else cfg.frontend_tokens
        batch_dict["frontend"] = np.random.default_rng(0).normal(
            0, 1, (b, n, cfg.d_model)).astype(np.float32)
    return batch_dict


def _round_batches(model, cfg, fl, args, seed):
    data = lm_round_batches(cfg.vocab_size, fl.serial_clients_in_step,
                            fl.local_steps_in_step, args.batch, args.seq, seed)
    if cfg.enc_layers > 0 or (cfg.frontend != "none" and cfg.frontend_tokens):
        n = cfg.enc_seq if cfg.enc_layers else cfg.frontend_tokens
        data["frontend"] = np.random.default_rng(seed).normal(
            0, 1, (fl.serial_clients_in_step, fl.local_steps_in_step,
                   args.batch, n, cfg.d_model)).astype(np.float32)
    return jax.tree.map(jnp.asarray, data)


def _eval_batch(model, cfg, b, s, seed):
    d = lm_eval_batch(cfg.vocab_size, b, s, seed + 999)
    d = _with_frontend(model, cfg, d, b)
    return jax.tree.map(jnp.asarray, d)


if __name__ == "__main__":
    main()
