"""CLI: batched decode serving on an assigned architecture (host scale).

PYTHONPATH=src python -m repro.launch.serve --arch recurrentgemma_9b \
    --batch 4 --prompt-len 16 --new-tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_arch
from repro.models.model import build


def prefill_scan(model, params, prompts, caches, *, window=None):
    """Prompt prefill as ONE dispatch: ``lax.scan`` of the decode step over
    the prompt positions, instead of one Python-loop dispatch per token.

    Token-for-token it runs the same ``decode_step`` math as the old loop
    (``prompts[:, t:t+1]`` becomes a ``dynamic_slice`` inside the scan), so
    the final logits and caches are bitwise identical
    (tests/test_serve.py pins it) — only the per-token host→device dispatch
    overhead disappears, which on short CAN-scale prompts is most of the
    prefill wall.  Returns ``(last_logits, caches)``.
    """
    def step(c, t):
        tok = jax.lax.dynamic_slice_in_dim(prompts, t, 1, axis=1)
        logits, c = model.decode_step(params, tok, c, t, window=window)
        return c, logits

    caches, ys = jax.lax.scan(step, caches,
                              jnp.arange(prompts.shape[1], dtype=jnp.int32))
    return ys[-1], caches


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="mamba2_130m")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=not args.full)
    model = build(cfg)
    window = cfg.sliding_window
    print(f"== serving {cfg.name} (window={window}) ==")

    params = model.init(jax.random.key(args.seed))
    cache_len = args.prompt_len + args.new_tokens
    caches = model.init_cache(args.batch, cache_len, params=params, window=window)
    decode = jax.jit(lambda p, t, c, i: model.decode_step(p, t, c, i, window=window))

    key = jax.random.key(args.seed + 1)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, jnp.int32)
    t0 = time.time()
    logits, caches = prefill_scan(model, params, prompts, caches,
                                  window=window)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    def sample(lg, k):
        lg = lg[:, 0, :cfg.vocab_size]
        if args.temperature <= 0:
            return jnp.argmax(lg, axis=-1)[:, None].astype(jnp.int32)
        return jax.random.categorical(k, lg / args.temperature)[:, None].astype(jnp.int32)

    toks = []
    t0 = time.time()
    tok = sample(logits, key)
    for t in range(args.prompt_len, cache_len):
        toks.append(tok)
        logits, caches = decode(params, tok, caches, jnp.asarray(t))
        tok = sample(logits, jax.random.fold_in(key, t))
    jax.block_until_ready(logits)
    t_decode = time.time() - t0
    gen = jnp.concatenate(toks, axis=1)
    print(f"  prefill {args.prompt_len} tokens: {t_prefill:.2f}s; "
          f"decode {args.new_tokens} tokens: {t_decode:.2f}s "
          f"({args.batch*args.new_tokens/t_decode:.1f} tok/s)")
    for i in range(min(args.batch, 2)):
        print(f"  request {i}: {np.asarray(gen[i])[:16].tolist()} ...")


if __name__ == "__main__":
    main()
