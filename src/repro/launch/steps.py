"""Step builders: (arch × input-shape × mesh) → lowered-ready jitted steps.

Produces, for every combination, a ``StepBundle``:
    fn            — the step function (FL round / prefill / decode)
    in_specs      — ShapeDtypeStruct pytree of every input (no allocation)
    in_shardings / out_shardings — NamedSharding pytrees for jax.jit
so that ``launch/dryrun.py`` is a thin loop around
``jit(fn, in_shardings, out_shardings).lower(*in_specs).compile()``.

Execution-profile policy (DESIGN.md §4):
    param_count < 10B  → client_parallel (clients on the data axes)
    otherwise          → client_serial  (whole mesh per client, FSDP)
grad_accum is chosen so the per-chip activation microbatch is ~1-2
sequences for the ≥10B models.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import FLConfig, MeshConfig, ModelConfig, ShapeConfig
from repro.core import plans as plans_lib
from repro.core import rounds as rounds_lib
from repro.models.model import Model, build, effective_window
from repro.models.sharding import logical_to_pspec, make_rules, sanitize_pspec
from repro.models.shardctx import sharding_ctx

PARALLEL_PLAN_MAX_PARAMS = 10e9


@dataclass
class StepBundle:
    name: str
    fn: Callable
    in_specs: Tuple
    in_shardings: Tuple
    out_shardings: Any
    meta: Dict[str, Any]


# ---------------------------------------------------------------------------
# Profiles
# ---------------------------------------------------------------------------


def choose_plan(cfg: ModelConfig) -> str:
    return (
        "client_parallel"
        if cfg.param_count() < PARALLEL_PLAN_MAX_PARAMS
        else "client_serial"
    )


def choose_grad_accum(cfg: ModelConfig, per_shard_batch: int) -> int:
    n = cfg.param_count()
    if n >= 50e9:
        target = 1
    elif n >= 10e9:
        target = 2
    else:
        return 1
    return max(1, per_shard_batch // target)


def make_fl_config(cfg: ModelConfig, plan: str, n_clients: int) -> FLConfig:
    return FLConfig(
        n_clients=n_clients,
        # coherence scoring costs a params-size all-reduce per client in the
        # parallel plan — keep it for sub-B models, off for multi-B LMs
        coherence_scoring=cfg.param_count() < 1e9,
        clients_per_round=max(2, n_clients // 4),
        adaptive_k=True,
        local_lr=0.01,
        dp_enabled=True,
        dp_mode="clipped",
        dp_epsilon=8.0,
        dp_clip=1.0,
        fault_tolerance=True,
        failure_prob=0.05,
        plan=plan,
        serial_clients_in_step=2,
        local_steps_in_step=1,
    )



def _scan_correction(cfg: ModelConfig, mode: str, clients_scan: int = 1,
                     local_steps: int = 1, grad_accum: int = 1) -> dict:
    """XLA cost_analysis counts while-loop (scan) bodies ONCE, not x trips
    (verified empirically — see EXPERIMENTS.md §Roofline).  We record the
    known static trip structure so the roofline can correct HLO-derived
    flops/bytes/collectives for the scanned stacks.

    layers_mult is approximate for heterogeneous stacks (segments of
    different super-blocks are averaged); exact for uniform ones.
    """
    segs = cfg.segments()
    blocks_counted = sum(len(kinds) for kinds, _ in segs)
    total_blocks = sum(len(kinds) * reps for kinds, reps in segs)
    layers_mult = total_blocks / max(blocks_counted, 1)
    if cfg.enc_layers:
        # encoder scan (trip enc_layers) + decoder scan (trip n_layers),
        # each counted once
        layers_mult = (cfg.enc_layers + cfg.n_layers) / 2.0
    product = layers_mult * clients_scan * local_steps * grad_accum
    return {
        "layers_mult": layers_mult,
        "clients_scan": clients_scan,
        "local_steps": local_steps,
        "grad_accum": grad_accum,
        "product": product,
    }


# ---------------------------------------------------------------------------
# Sharding helpers
# ---------------------------------------------------------------------------


def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def param_shardings(model: Model, rules: dict, mesh: Mesh):
    axes = model.axes()
    shapes = model.param_shapes()

    def one(a, s):
        return _ns(mesh, sanitize_pspec(s.shape, logical_to_pspec(a, rules), mesh))

    return jax.tree.map(
        one, axes, shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            y is None or isinstance(y, str) for y in x
        ),
    )


def replicated(mesh: Mesh, tree):
    return jax.tree.map(lambda _: _ns(mesh, P()), tree)


def batch_axes(rules: dict):
    ab = rules.get("act_batch")
    return ab if ab else None


def cache_shardings(cache_specs, rules: dict, mesh: Mesh, *,
                    ssm_shard: str = "heads"):
    """Decode-cache shardings by leaf name (DESIGN.md §5):
      k/v   [L,B,C,H,D]  → batch over data axes, cache SEQ over model
                            (context-parallel decode; kv heads replicated)
      h     [L,B,W]      → recurrent width over model
      conv  [L,B,K,C]    → channel dim over model
      ssm   [L,B,H,P,N]  → ``ssm_shard``: "heads" puts model on H (falls back
                            to replicated when H doesn't divide — e.g. 24
                            heads on a 16-way axis); "state" puts it on N
                            (the SSD state dim, 128 — always divides).
    """
    ab = batch_axes(rules)

    def spec_for(path, leaf):
        name = None
        for pp in reversed(path):
            if hasattr(pp, "key"):
                name = str(pp.key)
                break
        nd = len(leaf.shape)
        if name in ("k", "v"):
            s = P(None, ab, "model", None, None) if nd == 5 else P(ab, "model", None, None)
        elif name == "h":
            s = P(None, ab, "model") if nd == 3 else P(ab, "model")
        elif name == "conv":
            if ssm_shard == "state_convrep":
                s = P(None, ab, None, None) if nd == 4 else P(ab, None, None)
            else:
                s = P(None, ab, None, "model") if nd == 4 else P(ab, None, "model")
        elif name == "ssm":
            if ssm_shard in ("state", "state_convrep"):
                s = (P(None, ab, None, None, "model") if nd == 5
                     else P(ab, None, None, "model"))
            else:
                s = (P(None, ab, "model", None, None) if nd == 5
                     else P(ab, "model", None, None))
        else:
            s = P()
        return _ns(mesh, sanitize_pspec(leaf.shape, s, mesh))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_specs)
    return jax.tree_util.tree_unflatten(treedef, [spec_for(p, l) for p, l in flat])


def _client_axes(mesh_cfg: MeshConfig):
    return ("pod", "data") if mesh_cfg.multi_pod else ("data",)


def _mesh_size(mesh_cfg: MeshConfig, axes) -> int:
    sizes = dict(zip(mesh_cfg.axes, mesh_cfg.shape))
    n = 1
    for a in axes:
        n *= sizes[a]
    return n


# ---------------------------------------------------------------------------
# Train step (one FL communication round on the assigned architecture)
# ---------------------------------------------------------------------------


def build_train_step(cfg: ModelConfig, shape: ShapeConfig, mesh_cfg: MeshConfig,
                     mesh: Mesh, *, plan: Optional[str] = None,
                     grad_accum: Optional[int] = None,
                     remat: str = "full",
                     remat_group: int = 1,
                     rules_override: Optional[dict] = None) -> StepBundle:
    model = build(cfg)
    plan = plan or choose_plan(cfg)
    # Resolve the plan against the core/plans registry ONCE: everything
    # below branches on the STATIC program family, so a registered
    # same-family plan (buffered_async / hierarchical ride the
    # client_parallel program) needs no new branch here.
    plan_spec = plans_lib.get_plan(plan)
    family = plan_spec.family
    rules = dict(rules_override or make_rules(plan, mesh_cfg.multi_pod))
    client_axes = _client_axes(mesh_cfg)
    n_client_slots = _mesh_size(mesh_cfg, client_axes)
    data_shards = _mesh_size(mesh_cfg, client_axes)

    if family == "client_parallel":
        n_clients = n_client_slots
        per_client_batch = max(1, shape.global_batch // n_clients)
        ga = 1
    else:
        n_clients = 40  # paper's population; K slots folded into the step
        per_client_batch = shape.global_batch
        per_shard = max(1, per_client_batch // data_shards)
        ga = grad_accum if grad_accum is not None else choose_grad_accum(cfg, per_shard)

    fl = make_fl_config(cfg, plan, n_clients)
    loss_fn = lambda p, b: model.loss(p, b, remat=remat, remat_group=remat_group)

    # ---- input specs -------------------------------------------------------
    base = model.input_specs(dataclasses.replace(shape, global_batch=per_client_batch))
    steps = fl.local_steps_in_step
    lead = (n_clients, steps) if family == "client_parallel" else (
        fl.serial_clients_in_step, steps)
    batches = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(lead + s.shape, s.dtype), base
    )

    params_spec = model.param_shapes()
    state_spec = jax.eval_shape(
        lambda p: rounds_lib.init_round_state(p, fl, jax.random.key(0),
                                              n_clients=n_clients),
        params_spec,
    )

    # ---- shardings ---------------------------------------------------------
    p_shard = param_shardings(model, rules, mesh)
    state_shard = rounds_lib.RoundState(
        params=p_shard,
        server_opt_state=jax.tree.map(lambda _: _ns(mesh, P()),
                                      state_spec.server_opt_state),
        util=jax.tree.map(lambda _: _ns(mesh, P()), state_spec.util),
        kctl=jax.tree.map(lambda _: _ns(mesh, P()), state_spec.kctl),
        round_idx=_ns(mesh, P()),
        rng=_ns(mesh, P()),
        fault=jax.tree.map(lambda _: _ns(mesh, P()), state_spec.fault),
    )
    if family == "client_parallel":
        lead_spec = (client_axes, None)
    else:
        ab = rules.get("act_batch")
        lead_spec = (None, None, ab)
    batch_shard = jax.tree.map(
        lambda s: _ns(mesh, sanitize_pspec(
            s.shape, P(*(lead_spec + (None,) * (len(s.shape) - len(lead_spec)))), mesh)),
        batches,
    )

    # ---- round builder ----------------------------------------------------
    builder = plan_spec.builder_fn()
    if family == "client_parallel":
        def delta_constraint(deltas, _axes=model.axes()):
            def one(d, a):
                # leading client axis pinned to the data mesh axes; inner
                # dims follow the parameter's own logical sharding
                inner = logical_to_pspec(tuple(a), rules)
                full = P(client_axes, *tuple(inner))
                return jax.lax.with_sharding_constraint(
                    d, _ns(mesh, sanitize_pspec(d.shape, full, mesh)))

            return jax.tree.map(
                one, deltas, _axes,
                is_leaf=lambda x: isinstance(x, tuple) and all(
                    y is None or isinstance(y, str) for y in x),
            )

        round_step = builder(
            loss_fn, fl, n_clients, grad_accum=ga, delta_constraint=delta_constraint
        )
        ctx_rules = None  # vmap over clients: no in-model constraints
    else:
        delta_dtype = jnp.bfloat16 if cfg.param_count() > 100e9 else jnp.float32
        round_step = builder(
            loss_fn, fl, n_clients, grad_accum=ga, delta_dtype=delta_dtype
        )
        ctx_rules = rules

    def step(state, batches):
        if ctx_rules is not None:
            with sharding_ctx(ctx_rules, mesh):
                return round_step(state, batches)
        return round_step(state, batches)

    metrics_spec = jax.eval_shape(step, state_spec, batches)[1]
    out_shardings = (state_shard, jax.tree.map(lambda _: _ns(mesh, P()), metrics_spec))

    tokens = (
        n_clients * steps * per_client_batch * shape.seq_len
        if plan == "client_parallel"
        else fl.serial_clients_in_step * steps * per_client_batch * shape.seq_len
    )
    return StepBundle(
        name=f"fl_round[{plan}]",
        fn=step,
        in_specs=(state_spec, batches),
        in_shardings=(state_shard, batch_shard),
        out_shardings=out_shardings,
        meta={
            "plan": plan, "grad_accum": ga, "tokens_per_step": tokens,
            "clients_in_step": (n_clients if plan == "client_parallel"
                                else fl.serial_clients_in_step),
            "per_client_batch": per_client_batch,
            "scan": _scan_correction(
                cfg, "train",
                clients_scan=(1 if plan == "client_parallel"
                              else fl.serial_clients_in_step),
                local_steps=steps, grad_accum=ga,
            ),
        },
    )


# ---------------------------------------------------------------------------
# Serve steps
# ---------------------------------------------------------------------------


def build_prefill_step(cfg: ModelConfig, shape: ShapeConfig, mesh_cfg: MeshConfig,
                       mesh: Mesh,
                       rules_override: Optional[dict] = None) -> StepBundle:
    model = build(cfg)
    rules = dict(rules_override or make_rules("client_serial", mesh_cfg.multi_pod))
    window = effective_window(cfg, shape)

    def step(params, batch):
        with sharding_ctx(rules, mesh):
            logits = model.forward(params, batch, window=window, last_only=True)
        return logits

    specs = model.input_specs(shape)
    ab = rules.get("act_batch")
    batch_shard = jax.tree.map(
        lambda s: _ns(mesh, sanitize_pspec(
            s.shape, P(*((ab,) + (None,) * (len(s.shape) - 1))), mesh)),
        specs,
    )
    p_spec = model.param_shapes()
    p_shard = param_shardings(model, rules, mesh)
    out_spec = jax.eval_shape(step, p_spec, specs)
    out_shard = _ns(mesh, sanitize_pspec(out_spec.shape, P(ab, None, "model"), mesh))
    return StepBundle(
        name="serve_prefill",
        fn=step,
        in_specs=(p_spec, specs),
        in_shardings=(p_shard, batch_shard),
        out_shardings=out_shard,
        meta={"window": window,
              "tokens_per_step": shape.global_batch * shape.seq_len,
              "scan": _scan_correction(cfg, "prefill")},
    )


def build_decode_step(cfg: ModelConfig, shape: ShapeConfig, mesh_cfg: MeshConfig,
                      mesh: Mesh,
                      rules_override: Optional[dict] = None,
                      ssm_shard: str = "state") -> StepBundle:
    model = build(cfg)
    rules = dict(rules_override or make_rules("client_serial", mesh_cfg.multi_pod))
    window = effective_window(cfg, shape)

    def step(params, token, caches, index):
        with sharding_ctx(rules, mesh):
            logits, new_caches = model.decode_step(params, token, caches, index,
                                                   window=window)
        return logits, new_caches

    specs = model.input_specs(shape)
    token_s, caches_s, index_s = specs["token"], specs["caches"], specs["index"]
    ab = rules.get("act_batch")
    p_spec = model.param_shapes()
    p_shard = param_shardings(model, rules, mesh)
    c_shard = cache_shardings(caches_s, rules, mesh, ssm_shard=ssm_shard)
    t_shard = _ns(mesh, sanitize_pspec(token_s.shape, P(ab, None), mesh))
    out_spec = jax.eval_shape(step, p_spec, token_s, caches_s, index_s)
    logits_shard = _ns(mesh, sanitize_pspec(out_spec[0].shape, P(ab, None, "model"), mesh))
    return StepBundle(
        name="serve_decode",
        fn=step,
        in_specs=(p_spec, token_s, caches_s, index_s),
        in_shardings=(p_shard, t_shard, c_shard, _ns(mesh, P())),
        out_shardings=(logits_shard, c_shard),
        meta={"window": window, "cache_len": shape.seq_len,
              "tokens_per_step": shape.global_batch,
              "scan": _scan_correction(cfg, "decode")},
    )


def build_step(cfg: ModelConfig, shape: ShapeConfig, mesh_cfg: MeshConfig, mesh: Mesh,
               **kw) -> StepBundle:
    if shape.mode == "train":
        return build_train_step(cfg, shape, mesh_cfg, mesh, **kw)
    if shape.mode == "prefill":
        return build_prefill_step(cfg, shape, mesh_cfg, mesh, **kw)
    return build_decode_step(cfg, shape, mesh_cfg, mesh, **kw)
