"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* the first
jax initialisation, and smoke tests must keep seeing 1 device.

Target hardware: TPU v5e pods — 16×16 = 256 chips per pod; the multi-pod
configuration is 2 pods = 512 chips with a leading "pod" axis (DCN between
pods, ICI within).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Tiny mesh over however many devices the host actually has (tests)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel), ("data", "model"))


def scale_mesh_shape(n_devices: int, n_lanes: int):
    """(lane, client) factorisation for :func:`make_scale_mesh`.

    Lanes are embarrassingly parallel (independent trials), so the lane
    axis takes as many devices as it can fill — ``gcd(n_devices,
    n_lanes)``-ish: the largest divisor of ``n_devices`` that is ≤
    ``n_lanes`` — and the remaining factor shards the client axis.  One
    device degenerates to (1, 1): the program is identical unsharded.
    """
    lane = 1
    for d in range(min(n_devices, max(n_lanes, 1)), 0, -1):
        if n_devices % d == 0:
            lane = d
            break
    return lane, n_devices // lane


def make_scale_mesh(n_lanes: int = 1, shape=None):
    """2-D ``(lane, client)`` mesh for the population engine (ISSUE 6):
    the sweep's seed×config lane axis extends PR 2's 1-D lane mesh, and
    the new ``client`` axis shards every per-client [N] array — the
    Population membership table, the UtilityState/FaultState carries and
    the selection score buffers (``models/sharding.py::
    population_shardings``).  ``shape=(lane, client)`` overrides the
    automatic factorisation (tests pin specific layouts); ``None`` on a
    single device returns ``None`` — callers compile the identical
    unsharded program.
    """
    devices = jax.devices()
    if shape is None:
        shape = scale_mesh_shape(len(devices), n_lanes)
    lane, client = shape
    if lane * client <= 1:
        return None
    import numpy as np
    return jax.sharding.Mesh(
        np.asarray(devices[: lane * client]).reshape(lane, client),
        ("lane", "client"))


# TPU v5e per-chip constants (roofline terms, EXPERIMENTS.md §Roofline)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW_PER_LINK = 50e9        # bytes/s per link (~)
