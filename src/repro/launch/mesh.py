"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* the first
jax initialisation, and smoke tests must keep seeing 1 device.

Target hardware: TPU v5e pods — 16×16 = 256 chips per pod; the multi-pod
configuration is 2 pods = 512 chips with a leading "pod" axis (DCN between
pods, ICI within).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Tiny mesh over however many devices the host actually has (tests)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel), ("data", "model"))


# TPU v5e per-chip constants (roofline terms, EXPERIMENTS.md §Roofline)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW_PER_LINK = 50e9        # bytes/s per link (~)
