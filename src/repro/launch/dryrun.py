import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST run before any other import: jax locks the device count on first use.

"""Multi-pod dry-run (assignment deliverable e).

For every (architecture × input shape) and both production meshes
(16×16 single-pod, 2×16×16 multi-pod) this:

  1. builds the step (FL train round / serve prefill / serve decode) with its
     in/out shardings (launch/steps.py),
  2. ``jax.jit(step, in_shardings, out_shardings).lower(*input_specs)`` —
     ShapeDtypeStruct stand-ins, zero allocation,
  3. ``.compile()`` — SPMD partitioning must succeed; sharding mismatches,
     unsupported collectives or compile-time OOM are bugs,
  4. records ``memory_analysis()`` / ``cost_analysis()`` / collective bytes
     (parsed from the post-SPMD optimized HLO) into
     ``benchmarks/artifacts/<arch>__<shape>__<mesh>.json``
     — the roofline analysis (benchmarks/roofline.py) reads these.

Usage:
  python -m repro.launch.dryrun --arch granite_3_8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--skip-existing]
"""
import argparse
import json
import re
import time
import traceback
from typing import Dict

import jax
import numpy as np

from repro.configs.base import ARCH_IDS, INPUT_SHAPES, MeshConfig, get_arch, get_shape
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_production_mesh

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "benchmarks", "artifacts")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]*)\]")


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes of every collective op in optimized HLO.

    Post-SPMD HLO is per-partition, so these are bytes *per device* entering
    the interconnect for each op instance (all-gather results count the
    gathered size; all-reduce counts the reduced buffer once — a ~2x
    ring-traffic underestimate that we keep consistent across archs).
    """
    out = {c: 0.0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)$", ls)
        if not m:
            continue
        rhs = m.group(1)
        for cname in _COLLECTIVES:
            # match "<type> all-reduce(" etc. (avoid "-start/-done" dupes:
            # count -start, skip -done)
            if f" {cname}(" in rhs or f" {cname}-start(" in rhs or rhs.startswith(cname):
                if f"{cname}-done" in rhs:
                    continue
                type_part = rhs.split(cname)[0]
                nbytes = 0.0
                for dt, dims in _SHAPE_RE.findall(type_part):
                    n = 1
                    for d in dims.split(","):
                        if d:
                            n *= int(d)
                    nbytes += n * _DTYPE_BYTES[dt]
                out[cname] += nbytes
                counts[cname] += 1
                break
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    out["counts"] = counts
    return out


def hbm_bytes_estimate(cost: dict) -> float:
    return float(cost.get("bytes accessed", 0.0))


def run_one(arch: str, shape_name: str, mesh_kind: str, *, save: bool = True,
            step_kw=None, tag: str = "") -> dict:
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    mesh_cfg = MeshConfig(multi_pod=(mesh_kind == "multi"))
    mesh = make_production_mesh(multi_pod=mesh_cfg.multi_pod)

    t0 = time.time()
    bundle = steps_lib.build_step(cfg, shape, mesh_cfg, mesh, **(step_kw or {}))
    with mesh:
        jitted = jax.jit(
            bundle.fn,
            in_shardings=bundle.in_shardings,
            out_shardings=bundle.out_shardings,
        )
        lowered = jitted.lower(*bundle.in_specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost_list = compiled.cost_analysis()
    cost = cost_list[0] if isinstance(cost_list, (list, tuple)) else cost_list
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    n_dev = mesh.devices.size
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": tag,
        "step": bundle.name, "meta": bundle.meta,
        "devices": n_dev,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "cost": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": hbm_bytes_estimate(cost),
            "transcendentals": float(cost.get("transcendentals", 0.0)),
        },
        "collectives": coll,
        "model_params": cfg.param_count(),
        "model_active_params": cfg.active_param_count(),
    }
    if save:
        os.makedirs(ARTIFACT_DIR, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        path = os.path.join(ARTIFACT_DIR, f"{arch}__{shape_name}__{mesh_kind}{suffix}.json")
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default=None)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES), default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    assert len(jax.devices()) == 512, (
        "dry-run expects 512 forced host devices; do not import jax before this module"
    )

    pairs = (
        [(a, s) for a in ARCH_IDS for s in INPUT_SHAPES]
        if args.all
        else [(args.arch, args.shape)]
    )
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch, shape in pairs:
        for mk in meshes:
            path = os.path.join(ARTIFACT_DIR, f"{arch}__{shape}__{mk}.json")
            if args.skip_existing and os.path.exists(path):
                print(f"[skip] {arch} {shape} {mk}")
                continue
            try:
                r = run_one(arch, shape, mk)
                print(
                    f"[ok]   {arch:24s} {shape:12s} {mk:6s} "
                    f"compile={r['compile_s']:7.1f}s "
                    f"flops={r['cost']['flops']:.3e} "
                    f"peak={(r['memory']['peak_bytes'] or 0)/2**30:.2f}GiB "
                    f"coll={r['collectives']['total']/2**30:.2f}GiB"
                )
            except Exception as e:  # noqa: BLE001 — report and continue
                failures.append((arch, shape, mk, repr(e)))
                print(f"[FAIL] {arch} {shape} {mk}: {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", *f[:3], f[3][:200])
        raise SystemExit(1)
    print("\nALL DRY-RUNS PASSED")


if __name__ == "__main__":
    main()
