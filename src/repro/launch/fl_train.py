"""CLI: federated training for the paper's anomaly-detection use case.

PYTHONPATH=src python -m repro.launch.fl_train \
    --dataset unsw --method proposed --rounds 100 --clients 40 \
    [--no-dp] [--no-ft] [--eps 50] [--selection adaptive_utility]
"""
from __future__ import annotations

import argparse
import dataclasses
import json

from repro.configs.base import FLConfig
from repro.data.synthetic import make_federated
from repro.train.fl_driver import METHODS, run_fl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", choices=["unsw", "road"], default="unsw")
    ap.add_argument("--method", choices=METHODS, default="proposed")
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--clients", type=int, default=40)
    ap.add_argument("--samples", type=int, default=12_000)
    ap.add_argument("--local-epochs", type=int, default=5)
    ap.add_argument("--eps", type=float, default=50.0)
    ap.add_argument("--clip", type=float, default=5.0)
    ap.add_argument("--no-dp", action="store_true")
    ap.add_argument("--no-ft", action="store_true")
    ap.add_argument("--fail-prob", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--alpha", type=float, default=0.5, help="Dirichlet non-IID")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()

    fed = make_federated(args.seed, args.dataset, n_samples=args.samples,
                         n_clients=args.clients, alpha=args.alpha)
    fl = FLConfig(
        n_clients=args.clients, clients_per_round=max(4, args.clients // 5),
        rounds=args.rounds, local_epochs=args.local_epochs, local_batch=32,
        local_lr=0.08, dp_enabled=not args.no_dp, dp_mode="clipped",
        dp_epsilon=args.eps, dp_clip=args.clip,
        fault_tolerance=not args.no_ft, failure_prob=args.fail_prob,
    )
    res = run_fl(fed, fl, args.method, seed=args.seed, rounds=args.rounds,
                 eval_every=max(args.rounds // 20, 1), dataset=args.dataset)
    print(f"\n{args.method} on {args.dataset}: acc={res.accuracy*100:.1f}% "
          f"auc={res.auc:.3f} sim_time={res.sim_time_s:.1f}s "
          f"eps_spent={res.eps_spent:.1f} wall={res.wall_time_s:.1f}s")
    for r, a, u, k in zip(res.history["round"], res.history["acc"],
                          res.history["auc"], res.history["k"]):
        print(f"  round {r:4d}: acc={a*100:5.1f}% auc={u:.3f} K={k:.0f}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(dataclasses.asdict(res), f, indent=1)
        print(f"wrote {args.json_out}")


if __name__ == "__main__":
    main()
