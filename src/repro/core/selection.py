"""Client selection (paper §IV-A, Algorithm 1).

Utility scores combine (i) performance contribution (EMA of local loss
improvement), (ii) data quality (size × label-entropy proxy), (iii) compute
capacity, (iv) a staleness/diversity bonus so rarely-selected clients are
revisited.  Scores drive SelectTopK; the *adaptive* controller grows K when
the global model plateaus and shrinks it while improvement is strong —
trading accuracy against cost as in F(S_t) = α·Accuracy − γ·Cost.

All strategy functions are jit-safe: they return a float mask over clients
and use a *static* k_max with a dynamic effective K (entries ranked below
K_t are zeroed), so a lowered round step supports adaptive K without
recompilation.

Registry: ``get_strategy(name)`` →
  adaptive_utility (ours) | random | acfl | power_of_choice | adafl
  (FedL2P is a personalization baseline — see experiments/fedl2p.py — it
  reuses ``random`` selection per its paper.)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig


class UtilityState(NamedTuple):
    """Per-client running statistics (all [n_clients] f32)."""

    perf_ema: jnp.ndarray          # EMA of local loss improvement
    loss_ema: jnp.ndarray          # EMA of local loss (ACFL uncertainty proxy)
    loss_var: jnp.ndarray          # EMA of squared loss deviation
    data_size: jnp.ndarray         # samples per client (normalised)
    data_quality: jnp.ndarray      # label-entropy proxy in [0, 1]
    coherence: jnp.ndarray         # EMA of cos(delta_i, aggregated delta) —
                                   # the observable data-quality signal: a
                                   # client with corrupted labels pushes
                                   # against the consensus update
    compute: jnp.ndarray           # relative compute capacity
    comm_cost: jnp.ndarray         # relative communication cost
    last_selected: jnp.ndarray     # rounds since last participation
    participation: jnp.ndarray     # cumulative selection count
    fail_ema: jnp.ndarray          # EMA of observed failures among rounds the
                                   # client was selected — the reliability
                                   # signal the fault engine feeds back into
                                   # selection (docs/DESIGN.md §6)


def init_utility_state(n: int, key=None, data_size=None, data_quality=None,
                       compute=None, comm_cost=None) -> UtilityState:
    ones = jnp.ones((n,), jnp.float32)
    if key is not None:
        k1, k2 = jax.random.split(key)
        compute = compute if compute is not None else jax.random.uniform(
            k1, (n,), minval=0.3, maxval=1.0)
        comm_cost = comm_cost if comm_cost is not None else jax.random.uniform(
            k2, (n,), minval=0.2, maxval=1.0)
    return UtilityState(
        perf_ema=jnp.zeros((n,), jnp.float32),
        loss_ema=ones * 2.0,
        loss_var=ones,
        data_size=(data_size if data_size is not None else ones),
        data_quality=(data_quality if data_quality is not None else ones),
        coherence=jnp.zeros((n,), jnp.float32),
        compute=(compute if compute is not None else ones),
        comm_cost=(comm_cost if comm_cost is not None else ones * 0.5),
        last_selected=jnp.zeros((n,), jnp.float32),
        participation=jnp.zeros((n,), jnp.float32),
        fail_ema=jnp.zeros((n,), jnp.float32),
    )


def compute_utility(state: UtilityState, fl: FLConfig,
                    fault_w=None) -> jnp.ndarray:
    """U_i — the paper's multi-factor utility score.

    F(S_t) = α·Accuracy(S_t) − γ·Cost(S_t): the per-client marginal of the
    accuracy term is the perf/data factors; the cost term subtracts
    communication+computation cost (Cost_i = Comm_i + Comp_i).

    ``fault_w`` is the RUNTIME reliability-coupling weight
    (``FLParams.fault_util_w``): unreliable clients' utility decays by
    ``fault_w · fail_ema`` so the top-k mask — and, through the resulting
    global loss, the adaptive-K controller — react to failure-prone
    cohorts (the paper's selection×fault interplay).  The default weight
    is 0.0, which is an exact no-op: default lanes stay bitwise identical
    to the pre-fault-engine selection stream.
    """
    ds = state.data_size / jnp.maximum(jnp.mean(state.data_size), 1e-9)
    # NOTE (validated in EXPERIMENTS.md §Paper-claims): raw local-loss
    # improvement ANTI-selects under label corruption — noisy clients
    # "improve" more because they fit their own noise from a worse start.
    # Update coherence is the reliable quality observable, so it carries the
    # dominant weight; perf is kept small as a convergence-speed signal.
    perf = 0.3 * state.perf_ema
    quality = 0.25 * state.data_quality * jnp.log1p(ds) + 5.0 * state.coherence
    capacity = state.compute
    staleness = jnp.log1p(state.last_selected) * 0.1  # exploration bonus
    cost = state.comm_cost + (1.0 / jnp.maximum(capacity, 0.1)) * 0.5
    base = fl.alpha * (perf + quality + 0.2 * capacity) - fl.gamma * cost + staleness
    if fault_w is None:
        return base
    # fail_ema >= 0 and finite, so fault_w == 0.0 subtracts an exact +0.0:
    # the coupling is bitwise-free until a lane turns it on.
    return base - fault_w * state.fail_ema


# ---------------------------------------------------------------------------
# Strategies — (key, state, utility, avail_mask, k_eff, k_max, explore) ->
# mask [n].  ``explore`` is the RUNTIME selection temperature (Gumbel noise
# scale, FLParams.explore_noise): a traced scalar is fine, so temperature
# sweeps never recompile.
#
# Each strategy is a SCORE function (key, state, utility, avail, explore)
# -> scores [n] plus the shared top-k masking.  The split exists for the
# population engine: at 10^5+ clients the cohort plan consumes the scores
# directly (``cohort_topk`` → gather), never materialising dense masks per
# round — while the dense ``sel_*`` wrappers below compose the SAME score
# functions with ``_topk_mask``, op for op what they inlined before the
# split, so default small-N lanes stay bitwise unchanged (ENGINE_REV
# models4; tests/test_engine.py pins the engine against the legacy oracle).
# ---------------------------------------------------------------------------


def _topk_mask(scores: jnp.ndarray, avail: jnp.ndarray, k_eff, k_max: int):
    """Float mask selecting the dynamic top-k_eff of the static top-k_max."""
    neg = jnp.finfo(jnp.float32).min
    masked = jnp.where(avail > 0, scores, neg)
    _, idx = jax.lax.top_k(masked, k_max)
    ranks = jnp.arange(k_max)
    take = (ranks < k_eff).astype(jnp.float32)
    mask = jnp.zeros_like(scores).at[idx].add(take)
    # never select unavailable clients even if k_eff > #available
    return mask * (avail > 0)


def score_adaptive_utility(key, state, utility, avail, explore=0.05):
    """Ours: utility with ε-greedy Gumbel exploration noise."""
    return utility + explore * jax.random.gumbel(key, utility.shape)


def score_random(key, state, utility, avail, explore=0.05):
    return jax.random.uniform(key, utility.shape)


def score_acfl(key, state, utility, avail, explore=0.05):
    """ACFL-style active selection: uncertainty sampling — prefer clients
    with high loss level & variance (most informative)."""
    uncertainty = state.loss_ema + jnp.sqrt(jnp.maximum(state.loss_var, 0.0))
    return uncertainty + explore * jax.random.gumbel(key, utility.shape)


def score_adafl(key, state, utility, avail, explore=0.05):
    """AdaFL: current + historical contribution, no cost/staleness terms."""
    hist = state.perf_ema + 0.1 * state.participation / jnp.maximum(
        jnp.max(state.participation), 1.0
    )
    return hist + explore * jax.random.gumbel(key, utility.shape)


def sel_adaptive_utility(key, state, utility, avail, k_eff, k_max,
                         explore=0.05):
    """Ours: top-K by utility with ε-greedy exploration noise."""
    return _topk_mask(score_adaptive_utility(key, state, utility, avail,
                                             explore), avail, k_eff, k_max)


def sel_random(key, state, utility, avail, k_eff, k_max, explore=0.05):
    return _topk_mask(score_random(key, state, utility, avail, explore),
                      avail, k_eff, k_max)


def sel_acfl(key, state, utility, avail, k_eff, k_max, explore=0.05):
    return _topk_mask(score_acfl(key, state, utility, avail, explore),
                      avail, k_eff, k_max)


def sel_power_of_choice(key, state, utility, avail, k_eff, k_max,
                        explore=0.05):
    """Power-of-choice: sample d=2·k_max candidates, keep highest-loss K.

    The candidate stage needs k_max, so it has no plain score function —
    the population engine composes its own two-stage cohort_topk instead.
    """
    d = min(2 * k_max, avail.shape[0])
    cand = _topk_mask(jax.random.uniform(key, utility.shape), avail, d, d)
    scores = jnp.where(cand > 0, state.loss_ema, jnp.finfo(jnp.float32).min)
    return _topk_mask(scores, avail, k_eff, k_max)


def sel_adafl(key, state, utility, avail, k_eff, k_max, explore=0.05):
    return _topk_mask(score_adafl(key, state, utility, avail, explore),
                      avail, k_eff, k_max)


_SCORES = {
    "adaptive_utility": score_adaptive_utility,
    "random": score_random,
    "acfl": score_acfl,
    "adafl": score_adafl,
}


def get_score_fn(name: str) -> Callable:
    """Score function for the population engine's cohort plan.  Strategies
    whose selection is not a single score pass (power_of_choice) are not
    cohort-plan capable and raise."""
    try:
        return _SCORES[name]
    except KeyError:
        raise ValueError(
            f"selection strategy {name!r} has no score function — the "
            f"population cohort plan supports {tuple(_SCORES)}") from None


def cohort_strategy_names():
    return tuple(_SCORES)


# ---------------------------------------------------------------------------
# On-device cohort sampling (the population engine, ISSUE 6)
# ---------------------------------------------------------------------------


def cohort_topk(scores: jnp.ndarray, avail: jnp.ndarray, k_eff, k_max: int,
                chunks: int = 1):
    """Top-``k_max`` cohort of a (possibly huge, possibly sharded) score
    vector: ``(idx [k_max] i32, take [k_max] f32)``.

    The index form of :func:`_topk_mask` — ``zeros(n).at[idx].add(take)``
    reproduces its dense mask exactly (pinned in tests/test_scale.py) —
    but the engine consumes ``idx`` directly: gather the ceil(k_eff)
    cohort's membership/state to the compute lanes instead of training all
    N clients against a mask.  ``take`` zeroes both the ranks at or above
    the dynamic ``k_eff`` and any slot that fell to an unavailable client
    (possible when k_eff exceeds the number available).

    ``chunks`` > 1 splits the score scan into equal pieces and merges the
    per-chunk top-k — the auto-chunking policy (``core/scale.py``) uses it
    to bound the selection working set when N-shaped f32 temporaries
    exceed the per-device budget.  The merge is BITWISE the unchunked
    selection: ``lax.top_k`` breaks ties by lower index, and the merged
    candidate list is ordered chunk-major then index-major, which is
    exactly global index order among equal values.
    """
    # metadata-only profiler marker (docs/DESIGN.md §8) — the population
    # engine's selection phase shows up named in TensorBoard traces
    with jax.named_scope("cohort_topk"):
        neg = jnp.finfo(jnp.float32).min
        masked = jnp.where(avail > 0, scores, neg)
        n = masked.shape[0]
        chunks = int(chunks)
        if chunks > 1 and n % chunks == 0 and n // chunks >= k_max:
            per = n // chunks
            v, i = jax.lax.top_k(masked.reshape(chunks, per), k_max)
            i = i + (jnp.arange(chunks, dtype=i.dtype) * per)[:, None]
            vals, j = jax.lax.top_k(v.reshape(-1), k_max)
            idx = i.reshape(-1)[j]
        else:
            vals, idx = jax.lax.top_k(masked, k_max)
        ranks = jnp.arange(k_max)
        take = (ranks < k_eff).astype(jnp.float32) * (vals > neg)
        return idx.astype(jnp.int32), take


def cohort_topk_host(scores, avail, k_eff: float, k_max: int):
    """Host-side NumPy reference draw for :func:`cohort_topk` — same
    tie-breaking (stable sort ≡ ``lax.top_k``'s lower-index-first), same
    availability masking.  The property tests pin the on-device cohort
    against this bitwise at small N (tests/test_scale.py)."""
    import numpy as np
    scores = np.asarray(scores, np.float32)
    avail = np.asarray(avail)
    neg = np.finfo(np.float32).min
    masked = np.where(avail > 0, scores, neg).astype(np.float32)
    idx = np.argsort(-masked, kind="stable")[:k_max]
    take = ((np.arange(k_max) < k_eff) & (masked[idx] > neg)).astype(
        np.float32)
    return idx.astype(np.int32), take


_STRATEGIES = {
    "adaptive_utility": sel_adaptive_utility,
    "random": sel_random,
    "acfl": sel_acfl,
    "power_of_choice": sel_power_of_choice,
    "adafl": sel_adafl,
}


def get_strategy(name: str) -> Callable:
    return _STRATEGIES[name]


def strategy_names():
    return tuple(_STRATEGIES)


# ---------------------------------------------------------------------------
# Adaptive-K controller
# ---------------------------------------------------------------------------


class KControllerState(NamedTuple):
    k: jnp.ndarray            # current K (f32 for jit friendliness)
    best_metric: jnp.ndarray  # best global metric seen
    plateau: jnp.ndarray      # consecutive rounds without improvement


def init_k_state(fl: FLConfig) -> KControllerState:
    return KControllerState(
        k=jnp.asarray(float(fl.clients_per_round), jnp.float32),
        best_metric=jnp.asarray(jnp.inf, jnp.float32),
        plateau=jnp.zeros((), jnp.float32),
    )


def update_k(state: KControllerState, global_loss, fl: FLConfig,
             tol=None, patience=None) -> KControllerState:
    """Grow K on plateau (need more signal), shrink while improving fast
    (save Cost(S_t)); clamp to [k_min, k_max].

    ``tol``/``patience`` default to the config's ``k_tol``/``k_patience``;
    the engine passes its runtime FLParams values instead (traced scalars are
    fine — threshold sweeps share one compiled program)."""
    tol = fl.k_tol if tol is None else tol
    patience = fl.k_patience if patience is None else patience
    k_max = float(fl.k_max or fl.n_clients)
    improved = global_loss < state.best_metric * (1.0 - tol)
    plateau = jnp.where(improved, 0.0, state.plateau + 1.0)
    grow = plateau >= patience
    k = jnp.where(grow, state.k + jnp.maximum(1.0, 0.25 * state.k), state.k)
    # Strong-shrink needs EVIDENCE of fast improvement: best_metric starts
    # at +inf, where `loss < inf·(1−10·tol)` is trivially true — without the
    # finite gate the controller shrank K on round 1 having observed nothing
    # (ISSUE 4 bugfix; regression test in tests/test_models.py).
    strong = (jnp.isfinite(state.best_metric)
              & (global_loss < state.best_metric * (1.0 - 10.0 * tol)))
    k = jnp.where(strong & ~grow, k - 1.0, k)
    k = jnp.clip(k, float(fl.k_min), k_max)
    return KControllerState(
        k=k,
        best_metric=jnp.minimum(state.best_metric, global_loss),
        plateau=jnp.where(grow, 0.0, plateau),
    )


# ---------------------------------------------------------------------------
# Utility-state update after a round
# ---------------------------------------------------------------------------


def update_utility_state(state: UtilityState, sel_mask, pre_loss, post_loss,
                         fl: FLConfig, coherence=None, attempted=None,
                         failed=None) -> UtilityState:
    """EMA updates from this round's local training results.

    pre/post_loss: [n] local loss before/after local training; only selected
    clients' stats move.  ``coherence``: [n] cos(delta_i, agg_delta) for the
    selected clients (0 elsewhere) — the update-quality signal.

    ``attempted``/``failed``: the fault engine's reliability observables —
    ``attempted`` is the ORIGINAL selection mask (a failed client was still
    selected; ``sel_mask`` here is the contribution mask, which excludes
    it) and ``failed`` the per-client failure indicator.  Every attempted
    client's ``fail_ema`` moves toward its failure outcome; omitting them
    (legacy callers, the serial plan) leaves ``fail_ema`` untouched.
    """
    m = sel_mask > 0
    improvement = jnp.maximum(pre_loss - post_loss, -1.0)
    e = fl.utility_ema
    perf = jnp.where(m, (1 - e) * state.perf_ema + e * improvement, state.perf_ema)
    loss_ema = jnp.where(m, (1 - e) * state.loss_ema + e * post_loss, state.loss_ema)
    dev = (post_loss - loss_ema) ** 2
    loss_var = jnp.where(m, (1 - e) * state.loss_var + e * dev, state.loss_var)
    coh = state.coherence
    if coherence is not None:
        coh = jnp.where(m, (1 - e) * coh + e * coherence, coh)
    fail_ema = state.fail_ema
    if failed is not None:
        att = (attempted if attempted is not None else sel_mask) > 0
        fail_ema = jnp.where(
            att, (1 - e) * fail_ema + e * failed.astype(jnp.float32), fail_ema)
    return state._replace(
        perf_ema=perf,
        loss_ema=loss_ema,
        loss_var=loss_var,
        coherence=coh,
        last_selected=jnp.where(m, 0.0, state.last_selected + 1.0),
        participation=state.participation + sel_mask,
        fail_ema=fail_ema,
    )
