"""Fault tolerance (paper §IV "Handling training failures").

* Client failures follow a Weibull distribution (paper eq.):
      p_f(t_c) = 1 - exp(-(t_c / λ)^k)
* Total overhead balancing checkpoint cost vs recovery cost:
      C(t_c) = t_c/T + p_f(t_c) · t_r/T        (paper's cost model)

  REPRODUCTION NOTE (recorded in EXPERIMENTS.md): the paper's literal C(t_c)
  is monotonically increasing in t_c — both t_c/T and p_f(t_c) grow with
  t_c — so dC/dt_c = 0 has no interior solution and the "optimum" is
  t_c → 0.  The intended model is almost certainly the standard renewal
  form where *more frequent* checkpoints cost more and a failure loses the
  work since the last checkpoint: with write cost w,
      C_w(t_c) = [ w + p_f(t_c) · (t_c/2 + t_r) ] / t_c
  (per-interval write cost + expected rework, amortised), which has a proper
  interior minimum and recovers Young/Daly t_c* ≈ sqrt(2·w·MTBF) for
  exponential failures.  We implement the paper's formula verbatim
  (``write_cost=None``) and use the corrected variant for actual cadence.
* t_c* solves dC/dt_c = 0, found numerically (golden-section on a bracket).

Also: λ, k estimation from historical failure data (method of moments + MLE
via Newton on the shape parameter), and the legacy host-side
:class:`FailureModel` sampler.

This module is the HOST-SIDE half of the fault subsystem (cost analysis and
fitting).  Per-round failure *injection* inside the compiled engine lives in
``repro/fault/process.py`` — pluggable i.i.d. / Markov-bursty /
Weibull-lifetime / straggler processes selected by the runtime
``FLConfig.fault_process`` lane code, with per-client state threaded through
the engine's scan carry (docs/DESIGN.md §6).  ``repro.fault`` re-exports
both halves as one namespace.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def weibull_failure_prob(t_c, lam: float, k: float):
    """p_f(t_c) = 1 - exp(-(t_c/λ)^k)."""
    t = np.asarray(t_c, dtype=np.float64)
    return 1.0 - np.exp(-((t / lam) ** k))


def recovery_overhead(recovery_time, frac: float = 0.01):
    """Per-failed-client recovery term of the simulated round-time model
    (``train/fl_driver.simulate_round_time``): under fault tolerance a
    checkpoint restart resumes near the failure point, so only ``frac·t_r``
    is charged per failure.  Pure arithmetic — ``recovery_time`` is a
    runtime FLParams scalar (traced inside the engine), so failure-model
    sweeps ride one compiled program."""
    return recovery_time * frac


def checkpoint_cost(t_c, T: float, t_r: float, lam: float, k: float,
                    write_cost: Optional[float] = None):
    """Paper cost model C(t_c) = t_c/T + p_f(t_c)·t_r/T (write_cost=None),
    or the corrected renewal model (module docstring) with write cost w:
    C_w(t_c) = [w + p_f(t_c)·(t_c/2 + t_r)] / t_c."""
    t = np.asarray(t_c, dtype=np.float64)
    pf = weibull_failure_prob(t, lam, k)
    if write_cost is None:
        return t / T + pf * t_r / T
    t_safe = np.maximum(t, 1e-9)
    return (write_cost + pf * (t_safe / 2.0 + t_r)) / t_safe


def optimal_checkpoint_interval(T: float, t_r: float, lam: float, k: float,
                                write_cost: Optional[float] = None,
                                bracket: Tuple[float, float] = (1e-3, None)) -> float:
    """argmin_{t_c} C(t_c) by golden-section search (dC/dt=0 numerically)."""
    lo = bracket[0]
    hi = bracket[1] or max(T, 4.0 * lam)
    gr = (math.sqrt(5.0) - 1.0) / 2.0
    a, b = lo, hi
    c = b - gr * (b - a)
    d = a + gr * (b - a)
    for _ in range(200):
        if checkpoint_cost(c, T, t_r, lam, k, write_cost) < checkpoint_cost(
            d, T, t_r, lam, k, write_cost
        ):
            b = d
        else:
            a = c
        c = b - gr * (b - a)
        d = a + gr * (b - a)
        if abs(b - a) < 1e-6 * max(1.0, abs(b)):
            break
    return 0.5 * (a + b)


# ---------------------------------------------------------------------------
# Fitting λ, k from historical failure data
# ---------------------------------------------------------------------------


def fit_weibull(samples: Sequence[float], iters: int = 100) -> Tuple[float, float]:
    """MLE for (λ, k) from observed failure inter-arrival times.

    Newton iteration on the profile likelihood for k; λ in closed form.
    """
    x = np.asarray([s for s in samples if s > 0], dtype=np.float64)
    if x.size < 2:
        return float(np.mean(x) if x.size else 1.0), 1.0
    lx = np.log(x)
    k = 1.0
    for _ in range(iters):
        xk = x**k
        A = np.sum(xk * lx) / np.sum(xk)
        f = 1.0 / k - (A - np.mean(lx))
        # derivative of f wrt k
        B = np.sum(xk * lx * lx) / np.sum(xk) - A**2
        fp = -1.0 / k**2 - B
        step = f / fp
        k_new = k - step
        if not np.isfinite(k_new) or k_new <= 0:
            k_new = k / 2.0
        if abs(k_new - k) < 1e-10:
            k = k_new
            break
        k = k_new
    lam = float(np.mean(x**k) ** (1.0 / k))
    return lam, float(k)


# ---------------------------------------------------------------------------
# Failure injection
# ---------------------------------------------------------------------------


@dataclass
class FailureModel:
    """Per-round failure sampling for HOST-SIDE simulations.

    ``mode='bernoulli'`` draws RandomFailure(p_f) as in Algorithm 1;
    ``mode='weibull'`` samples a failure time within the round of duration
    ``round_time`` from Weibull(λ, k) and fails if it lands inside.

    Superseded inside the engine by the failure-scenario processes of
    ``repro/fault/process.py`` (which add correlated outages, lifetimes
    with memory and stragglers as runtime sweep lanes); kept for ad-hoc
    host-side analysis.
    """

    p_fail: float = 0.05
    mode: str = "bernoulli"
    lam: float = 600.0
    k: float = 1.2
    round_time: float = 30.0

    def sample(self, key, n_clients: int) -> jnp.ndarray:
        if self.mode == "bernoulli":
            return jax.random.bernoulli(key, self.p_fail, (n_clients,))
        u = jax.random.uniform(key, (n_clients,), minval=1e-9, maxval=1.0)
        t_fail = self.lam * (-jnp.log(u)) ** (1.0 / self.k)
        return t_fail < self.round_time

    def failure_step(self, key, n_clients: int, local_steps: int) -> jnp.ndarray:
        """Uniform step index at which each failing client dies (for
        checkpoint-recovery simulation); local_steps for survivors."""
        kf, ks = jax.random.split(key)
        fails = self.sample(kf, n_clients)
        step = jax.random.randint(ks, (n_clients,), 0, local_steps)
        return jnp.where(fails, step, local_steps)
