"""The execution-plan registry — RoundPlan, the engine's plan contract.

``FLConfig.plan`` used to be a bare string fanned out across three
hand-rolled builders in ``core/rounds.py``, with plan-specific special
cases leaking into ``train/fl_driver.py``, ``launch/steps.py`` and
``models/sharding.py``.  This module makes the contract explicit, the way
``models/spec.py`` did for detector architectures: a :class:`RoundPlan`
names everything the engine needs to know about a plan, and every dispatch
site resolves the registry instead of comparing strings (docs/DESIGN.md
§4).

The pieces a plan provides:

* **family** — the STATIC program family the plan lowers into.  This is
  the load-bearing field for compilation: ``fl_static`` canonicalises
  ``plan`` to its family, so every plan of one family shares ONE compiled
  program and the concrete plan choice becomes the RUNTIME lane ``code``
  (``FLParams.plan_code``), exactly like ``fault_process``/``dp_sched``.
  ``buffered_async`` and ``hierarchical`` share the ``client_parallel``
  family: the parallel round step always lowers the staleness-weighting
  and edge-aggregation machinery and selects it branch-free, which is what
  lets a mixed (sync × async × hierarchical) sweep compile once — and
  keeps code-0 lanes bitwise the pre-registry engine (``x·1.0`` and
  ``where(code≠…)`` identities; no new RNG draws on any lane).
* **code** — the runtime lane value within the family (0.0 = the family's
  base plan).
* **builder** — the ``core/rounds.py`` round-step builder name (resolved
  lazily via :meth:`RoundPlan.builder_fn` to keep this module import-light
  and cycle-free under ``configs/base.py``).
* **time_model** — which :func:`~repro.train.fl_driver.simulate_round_time`
  semantics the plan's simulated wall time follows (documentation of the
  branch-free select, not a dispatch key).
* **fault_arrivals** — whether the plan consumes the failure-scenario
  engine's arrival ordering (``repro.fault.arrival_score``): buffered-async
  ranks client arrivals by the straggler/Weibull processes' emitted
  ``slow`` factors and the per-client compute capacities.
* **driver_capable / cohort_capable** — which front doors accept the plan
  (``run_fl``/``run_fl_sweep`` vs ``run_fl_population``).  ``client_serial``
  is launch-path only: the dense driver used to SILENTLY run the parallel
  plan for it, which the registry now makes a loud error.
* **requires** — config-build-time validation (``FLConfig.__post_init__``
  calls :func:`validate_plan`), so a bad plan string or an incompatible
  plan/feature combination fails at construction instead of surfacing as a
  deep dispatch failure.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple


@dataclass(frozen=True)
class RoundPlan:
    """The registered execution-plan contract (see module docstring)."""

    name: str
    family: str              # STATIC program family (fl_static canonical form)
    code: float              # runtime lane value (FLParams.plan_code)
    builder: str             # round-step builder attribute in core/rounds.py
    time_model: str          # simulate_round_time semantics tag
    fault_arrivals: bool = False   # consumes repro.fault.arrival_score order
    driver_capable: bool = True    # run_fl / run_fl_sweep front door
    cohort_capable: bool = False   # run_fl_population front door
    description: str = ""
    # config-build-time cross-field validation: fl -> error message | None
    requires: Optional[Callable] = field(default=None, compare=False,
                                         repr=False)

    def builder_fn(self) -> Callable:
        """Resolve the round-step builder (lazy: core.rounds imports
        configs.base, which imports this module — resolving at call time
        keeps the triangle acyclic)."""
        from repro.core import rounds as rounds_lib
        return getattr(rounds_lib, self.builder)


_REGISTRY: Dict[str, RoundPlan] = {}


def register_plan(plan: RoundPlan) -> RoundPlan:
    if plan.name in _REGISTRY:
        raise ValueError(f"plan {plan.name!r} is already registered")
    _REGISTRY[plan.name] = plan
    return plan


def plan_names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def get_plan(name: str) -> RoundPlan:
    """Resolve a plan name; unknown names list the registry (the clear
    config-build-time error ISSUE 9 asks for)."""
    plan = _REGISTRY.get(name)
    if plan is None:
        raise ValueError(
            f"unknown FLConfig.plan {name!r}; registered plans: "
            f"{', '.join(sorted(_REGISTRY))}")
    return plan


def plan_family(name: str) -> str:
    """STATIC program family of a plan — what ``fl_static`` canonicalises
    ``FLConfig.plan`` to, so same-family plans share one compiled program."""
    return get_plan(name).family


def plan_code(name: str) -> float:
    """Runtime lane value of a plan (``FLParams.plan_code``)."""
    return get_plan(name).code


def plan_for_code(family: str, code: float) -> RoundPlan:
    """Inverse of (family, code) — used when a raw :class:`FLParams` cell
    carries a ``plan_code`` that differs from the base config's."""
    for plan in _REGISTRY.values():
        if plan.family == family and plan.code == float(code):
            return plan
    raise ValueError(f"no registered plan has family {family!r} "
                     f"and code {code!r}")


def validate_plan(fl) -> None:
    """Config-build-time plan validation (``FLConfig.__post_init__``).

    Rejects unknown plan names and plan/feature combinations the registry
    marks incompatible — e.g. ``buffered_async`` without a positive
    ``async_buffer``, or a sync plan with one (the buffer is the async
    plan's K; leaving it set on a sync config silently means something
    different from what was asked)."""
    plan = get_plan(fl.plan)
    if plan.requires is not None:
        msg = plan.requires(fl)
        if msg:
            raise ValueError(f"FLConfig.plan={fl.plan!r}: {msg}")
    if plan.name != "buffered_async" and float(fl.async_buffer) > 0:
        raise ValueError(
            f"FLConfig.plan={fl.plan!r} is not the buffered_async plan but "
            f"async_buffer={fl.async_buffer} is set — the buffer K only has "
            "meaning on the async plan (use plan='buffered_async', or leave "
            "async_buffer at 0)")


def _require_async_buffer(fl) -> Optional[str]:
    if float(fl.async_buffer) < 1:
        return (f"needs async_buffer >= 1 (the K of K-of-cohort "
                f"aggregation), got {fl.async_buffer}")
    return None


def _require_edges(fl) -> Optional[str]:
    if int(fl.hierarchy_edges) < 1:
        return (f"needs hierarchy_edges >= 1 (the static edge-aggregator "
                f"count), got {fl.hierarchy_edges}")
    return None


def _require_cohort_kmax(fl) -> Optional[str]:
    if not fl.k_max or int(fl.k_max) <= 0:
        return ("needs an explicit positive k_max (the static cohort size "
                "gathered to the compute lanes)")
    return None


register_plan(RoundPlan(
    name="client_parallel", family="client_parallel", code=0.0,
    builder="make_parallel_round", time_model="sync_slowest",
    driver_capable=True, cohort_capable=True,
    description=("synchronous FedAvg, clients vmapped on the data mesh "
                 "axes; the paper's plan and every default lane")))

register_plan(RoundPlan(
    name="client_serial", family="client_serial", code=0.0,
    builder="make_serial_round", time_model="sync_slowest",
    driver_capable=False, cohort_capable=False,
    description=("one client at a time with the whole mesh (FSDP); the "
                 "launch-path plan for >=10B models — not servable by the "
                 "dense driver (host feeds the K slots)")))

register_plan(RoundPlan(
    name="client_cohort", family="client_cohort", code=0.0,
    builder="make_cohort_round", time_model="sync_slowest",
    driver_capable=False, cohort_capable=True,
    requires=_require_cohort_kmax,
    description=("population-scale plan: on-device cohort top-k, O(k_max) "
                 "training — run_fl_population's execution form")))

register_plan(RoundPlan(
    name="buffered_async", family="client_parallel", code=1.0,
    builder="make_parallel_round", time_model="async_kth_arrival",
    fault_arrivals=True, driver_capable=True, cohort_capable=False,
    requires=_require_async_buffer,
    description=("FedBuff-style buffered async: the server applies the "
                 "aggregate once K updates arrive (arrival order from the "
                 "straggler/Weibull processes), late updates "
                 "staleness-discounted by (1+s)^-async_staleness_pow")))

register_plan(RoundPlan(
    name="hierarchical", family="client_parallel", code=2.0,
    builder="make_parallel_round", time_model="hier_two_tier",
    driver_capable=True, cohort_capable=False,
    requires=_require_edges,
    description=("two-tier edge->cloud FedAvg: clients FedAvg within "
                 "hierarchy_edges static edge groups (client i -> edge "
                 "i % E), the cloud means the live edge aggregates")))
