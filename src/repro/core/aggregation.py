"""Masked weighted aggregation of client updates (paper's global server).

The server computes  w_g ← w_g + server_opt( Σ_i m_i·n_i·Δ̃_i / Σ_i m_i·n_i )
where m_i is the selection×survival mask and n_i the client's sample count
(FedAvg weighting).  Two layouts:

* stacked  — Δ as [n_clients, ...] pytree leaves (client_parallel / vmap)
* streamed — running (weighted_sum, weight) carry (client_serial / scan)
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def aggregate_stacked(deltas, mask, weights):
    """deltas: pytree with leading client axis; mask/weights: [n]."""
    w = (mask * weights).astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(w), 1e-9)

    def agg(d):
        df = d.astype(jnp.float32)
        wb = w.reshape((-1,) + (1,) * (df.ndim - 1))
        return jnp.sum(df * wb, axis=0) / denom

    return jax.tree.map(agg, deltas)


def stream_init(params_like, dtype=jnp.float32):
    """Accumulator dtype is fp32 by default; ≥100B configs may pass bf16 to
    halve the accumulator footprint (DESIGN.md memory budget)."""
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, dtype), params_like)
    return zeros, jnp.zeros((), jnp.float32)


def stream_accumulate(carry, delta, m_i, n_i):
    acc, wsum = carry
    w = (m_i * n_i).astype(jnp.float32)
    acc = jax.tree.map(
        lambda a, d: (a.astype(jnp.float32) + w * d.astype(jnp.float32)).astype(a.dtype),
        acc, delta,
    )
    return acc, wsum + w


def stream_finalize(carry):
    acc, wsum = carry
    denom = jnp.maximum(wsum, 1e-9)
    return jax.tree.map(lambda a: (a.astype(jnp.float32) / denom), acc)


def apply_server_update(server_opt, params, opt_state, agg_delta):
    """w_g <- w_g + server_opt(Δ)."""
    agg = jax.tree.map(lambda d, p: d.astype(jnp.float32), agg_delta, params)
    return server_opt.update(agg, opt_state, params)
