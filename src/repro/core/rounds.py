"""The FL round engine — Algorithm 1 as a lowered JAX step.

The execution plans are registered :class:`~repro.core.plans.RoundPlan`
contracts (DESIGN.md §4); this module provides their round-step builders:

* ``client_parallel`` (:func:`make_parallel_round`): clients live on the
  leading axis of every batch leaf (sharded over the ``data``(×``pod``)
  mesh axes).  Local training runs as a ``vmap`` over clients; aggregation
  is a masked weighted mean over the client axis (GSPMD turns it into the
  all-reduce).
* ``buffered_async`` / ``hierarchical`` — RUNTIME lanes of the SAME
  ``client_parallel`` program family: the parallel round step always
  lowers the FedBuff staleness-weighting and the two-tier edge
  aggregation, and the ``FLParams.plan_code`` lane selects them
  branch-free (0 sync flat | 1 async | 2 hierarchical), exactly like the
  ``fault_process`` code.  Code-0 lanes are bitwise the pre-registry
  engine: the selects are ``where``/``·1.0`` identities and no lane draws
  new RNG — async arrival order derives from the failure-scenario
  engine's emitted ``slow`` factors and the per-client compute
  capacities (``repro.fault.arrival_score``), never from fresh keys.
* ``client_serial`` (:func:`make_serial_round`): one client at a time with
  the WHOLE mesh (FSDP over ``data``); ``lax.scan`` over the K selected
  clients.  This is the only plan that fits ≥100B-parameter models.
* ``client_cohort`` (:func:`make_cohort_round`): the population-scale
  form — O(k_max) training over an on-device-sampled cohort.

Fault-tolerance semantics inside a lowered step (DESIGN.md §6): failure
times come from the pluggable failure-scenario engine (``repro/fault``) —
the runtime ``FLParams.fault_process`` code selects i.i.d. / Markov-bursty /
Weibull-lifetime / straggler processes branch-free, and the per-client
process state (:class:`~repro.fault.process.FaultState`) rides in
:class:`RoundState` so the engine's scan threads it.  Each failing client
loses the work after its last checkpoint — with checkpointing every ``c``
local steps a failure at step f keeps ``c·⌊f/c⌋`` steps; without
checkpointing the failed client contributes nothing.  Stragglers keep all
their work but stretch the simulated round time via the emitted per-client
``slow`` factors (``RoundMetrics.slow``).  Time overheads are accounted by
the cost model in ``core/fault.py`` at the driver level.  The serial plan
keeps the historical i.i.d. draw (same keys, via
``repro.fault.iid_fail_times``) — non-i.i.d. processes are a
``client_parallel`` feature; see DESIGN.md §6.

Differential privacy: each selected client's update Δ_i is clipped and
noised (``core/dp.py``) *before* aggregation — noise on updates, never on
utility scores, exactly as the paper specifies.

Static/runtime split (docs/ARCHITECTURE.md): the builders close over the
STATIC part of ``FLConfig`` only (shapes, plan, strategy name, booleans
that gate code structure).  Scalar hyper-parameters — learning rates, DP
budget, failure/availability probabilities, selection temperature,
adaptive-K thresholds — enter the built ``round_step`` as a runtime
:class:`FLParams` pytree argument (``round_step(state, batches, params)``),
so one compiled step serves an entire hyper-parameter grid.  Omitting
``params`` falls back to the values baked in the builder's config, which
keeps the original two-argument call sites working unchanged.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig, FLParams, fl_params
from repro.core import aggregation as agg
from repro.core import dp as dp_lib
from repro.core import selection as sel_lib
from repro.fault import process as fault_proc
from repro.optim.optimizers import make_server_optimizer, sgd


class RoundState(NamedTuple):
    """Carried across communication rounds."""

    params: Any
    server_opt_state: Any
    util: sel_lib.UtilityState
    kctl: sel_lib.KControllerState
    round_idx: jnp.ndarray
    rng: jnp.ndarray
    fault: fault_proc.FaultState


class RoundMetrics(NamedTuple):
    sel_mask: jnp.ndarray
    avail: jnp.ndarray
    failed: jnp.ndarray
    pre_loss: jnp.ndarray
    post_loss: jnp.ndarray
    global_loss: jnp.ndarray
    k_effective: jnp.ndarray
    update_norms: jnp.ndarray
    slow: jnp.ndarray      # [n] round-time stretch factors (straggler process)


def init_round_state(params, fl: FLConfig, key, n_clients=None, **util_kw) -> RoundState:
    n = n_clients or fl.n_clients
    server = make_server_optimizer(fl.server_opt, fl.server_lr)
    return RoundState(
        params=params,
        server_opt_state=server.init(params),
        util=sel_lib.init_utility_state(n, key=key, **util_kw),
        kctl=sel_lib.init_k_state(fl),
        round_idx=jnp.zeros((), jnp.int32),
        rng=key,
        fault=fault_proc.init_fault_state(n),
    )


def microbatched_value_and_grad(loss_fn, grad_accum: int):
    """Gradient accumulation: batch leaves [B, ...] are split into
    ``grad_accum`` microbatches scanned sequentially — the activation
    working set shrinks by grad_accum× (essential for the ≥100B configs)."""
    if grad_accum <= 1:
        return jax.value_and_grad(loss_fn)

    def vag(params, batch):
        mb = jax.tree.map(
            lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum) + x.shape[1:]),
            batch,
        )

        def step(carry, b):
            loss_acc, g_acc = carry
            loss, g = jax.value_and_grad(loss_fn)(params, b)
            g_acc = jax.tree.map(lambda a, x: a + x.astype(jnp.float32), g_acc, g)
            return (loss_acc + loss, g_acc), None

        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, g), _ = jax.lax.scan(step, (jnp.zeros((), jnp.float32), zero_g), mb)
        scale = 1.0 / grad_accum
        return loss * scale, jax.tree.map(
            lambda gg, p: (gg * scale).astype(p.dtype), g, params
        )

    return vag


def _local_train_fn(loss_fn, fl: FLConfig, grad_accum: int = 1):
    """One client's local training: scan over local steps with step masking
    (effective_steps implements checkpoint-recovery truncation).

    ``lr`` is a runtime scalar (FLParams.local_lr) — a traced value is fine,
    so learning-rate sweeps share one compiled program."""
    vag = microbatched_value_and_grad(loss_fn, grad_accum)

    def local_train(global_params, step_batches, effective_steps, lr):
        opt = sgd(lr)

        def step(carry, xs):
            p, s = carry
            batch = xs
            loss, grads = vag(p, batch)
            new_p, _ = opt.update(grads, (), p)
            live = s < effective_steps
            # jnp.where keeps params in their storage dtype — no fp32
            # temporaries over the whole tree (2x param-size saving at 123B;
            # EXPERIMENTS.md §Perf A5)
            p = jax.tree.map(lambda a, b: jnp.where(live, b, a), p, new_p)
            return (p, s + 1), loss

        (p_final, _), losses = jax.lax.scan(
            step, (global_params, jnp.zeros((), jnp.float32)), step_batches
        )
        delta = jax.tree.map(
            lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
            p_final, global_params,
        )
        return delta, losses[0], losses[-1]

    return local_train


def _effective_steps(fail_step, local_steps: int, ckpt_every: int, ft_enabled: bool):
    """Steps of work that survive a failure at ``fail_step``."""
    failed = fail_step < local_steps
    if not ft_enabled:
        return jnp.where(failed, 0, local_steps), failed
    c = max(int(ckpt_every), 1)
    kept = (fail_step // c) * c
    return jnp.where(failed, kept, local_steps), failed


# ---------------------------------------------------------------------------
# client_parallel plan
# ---------------------------------------------------------------------------


def _dp_sigma(fl: FLConfig, pr: FLParams):
    """Noise scale from runtime params (trace-safe; dp_mode stays static).

    Scheduled-budget runs (``fl.dp_scheduled``, STATIC) read σ straight
    from ``pr.dp_sigma``: the driver injects the scheduler's per-round
    value there (``pr._replace(dp_sigma=σ_t)``), so a traced, per-round σ
    flows into the clip+noise kernels with no recompile.
    """
    if fl.dp_mode == "paper" or fl.dp_scheduled:
        return pr.dp_sigma
    return dp_lib.gaussian_sigma_rt(pr.dp_epsilon, fl.dp_delta, pr.dp_clip)


def _gate_server_update(update_gate, new_params, new_server_state,
                        state: RoundState):
    """Budget-exhaustion masking (repro/privacy): with ``update_gate`` ≤ 0
    the aggregated release is withheld — global params AND server-optimizer
    state stay bitwise frozen, exactly as a deployment that halts at
    exhaustion.  ``update_gate`` is a traced 0/1 scalar, so exhaustion can
    flip mid-scan without recompiling; ``None`` (every pre-existing caller)
    compiles the identical ungated program."""
    if update_gate is None:
        return new_params, new_server_state
    live = update_gate > 0
    new_params = jax.tree.map(lambda n, o: jnp.where(live, n, o),
                              new_params, state.params)
    new_server_state = jax.tree.map(lambda n, o: jnp.where(live, n, o),
                                    new_server_state, state.server_opt_state)
    return new_params, new_server_state


def make_parallel_round(loss_fn: Callable, fl: FLConfig, n_clients: int,
                        ckpt_every_steps: int = 2,
                        dp_use_kernel: Optional[bool] = None,
                        grad_accum: int = 1, delta_constraint=None):
    """Build ``round_step(state, batches, params=None, update_gate=None)
    -> (state, metrics)``.

    batches: pytree whose leaves have leading [n_clients, local_steps, ...].
    ``params``: runtime :class:`FLParams`; ``None`` uses the builder config's
    values (back-compat).  ``update_gate``: optional traced 0/1 scalar —
    the privacy subsystem's budget-exhaustion mask (see
    :func:`_gate_server_update`).  Only the STATIC part of ``fl`` is closed
    over.
    ``delta_constraint``: optional fn applied to the stacked client deltas —
    steps.py uses it to pin the client axis onto the data mesh axes so GSPMD
    never materialises every client's weights on one shard.
    ``dp_use_kernel=None`` (default) auto-routes the per-client clip+noise:
    the fused Pallas kernel (``kernels/dp_clip_noise.py``) when the backend
    is TPU, the ``kernels/ref.py`` jnp fallback on CPU — ``core/dp.py``'s
    accountant stays the source of truth for ε either way.
    """
    strategy = sel_lib.get_strategy(fl.selection)
    local_train = _local_train_fn(loss_fn, fl, grad_accum)
    k_max = int(fl.k_max or n_clients)
    default_params = fl_params(fl)

    def round_step(state: RoundState, batches,
                   params: Optional[FLParams] = None,
                   update_gate=None) -> Tuple[RoundState, RoundMetrics]:
        pr = default_params if params is None else params
        server = make_server_optimizer(fl.server_opt, pr.server_lr)
        rng, k_avail, k_sel, k_fail, k_dp = jax.random.split(state.rng, 5)

        # ---- GetAvailableClients (Alg.1 line 3) ----
        avail = jax.random.bernoulli(k_avail, pr.avail_prob,
                                     (n_clients,)).astype(jnp.float32)

        # ---- ComputeUtility + SelectTopK (line 4) ----
        # jax.named_scope markers are metadata-only (profiler/HLO names);
        # they never change the lowered math (docs/DESIGN.md §8)
        with jax.named_scope("selection"):
            utility = sel_lib.compute_utility(state.util, fl,
                                              fault_w=pr.fault_util_w)
            k_eff = (state.kctl.k if fl.adaptive_k
                     else jnp.asarray(float(fl.clients_per_round),
                                      jnp.float32))
            sel_mask = strategy(k_sel, state.util, utility, avail, k_eff,
                                k_max, pr.explore_noise)

        # ---- failure injection + checkpoint-recovery truncation ----
        # process-emitted failure times (repro/fault): the runtime
        # fault_process code picks iid/markov/weibull/straggler lanes
        # branch-free; the iid lane reproduces the historical draw bitwise
        local_steps = jax.tree.leaves(batches)[0].shape[1]
        fail_at, slow, new_fault = fault_proc.fault_step(
            state.fault, k_fail, pr, n_clients, local_steps)
        eff_steps, failed = _effective_steps(
            fail_at, local_steps, ckpt_every_steps, fl.fault_tolerance
        )

        # ---- local training, in parallel over clients (line 5) ----
        with jax.named_scope("local_train"):
            deltas, pre_loss, post_loss = jax.vmap(
                local_train, in_axes=(None, 0, 0, None)
            )(state.params, batches, eff_steps, pr.local_lr)
            if delta_constraint is not None:
                deltas = delta_constraint(deltas)

        # ---- DP: noise on updates, not on scores (lines 8-9) ----
        with jax.named_scope("dp_privatize"):
            if fl.dp_enabled:
                sigma = _dp_sigma(fl, pr)
                keys = jax.random.split(k_dp, n_clients)

                def privatize(d, k):
                    return dp_lib.privatize_update(
                        d, k, mode=fl.dp_mode, clip=pr.dp_clip, sigma=sigma,
                        use_kernel=dp_use_kernel,
                    )

                deltas, norms = jax.vmap(privatize)(deltas, keys)
            else:
                norms = jax.vmap(dp_lib.global_norm)(deltas)

        # drop clients whose surviving work is zero
        contrib_mask = sel_mask * (eff_steps > 0)

        # ---- buffered_async (plan_code 1): staleness-weighted arrivals ----
        # FedBuff semantics, eager-application form: every contributor's
        # update still lands this round, but discounted by how many K-sized
        # buffer flushes precede its arrival — staleness s_i = floor(rank/K),
        # weight (1+s)^-async_staleness_pow.  Arrival order comes from the
        # failure-scenario engine (straggler slow factors × compute
        # capacity), NOT from new draws, so every other lane's RNG stream is
        # untouched.  On non-async lanes the weight is exactly 1.0 and
        # contrib·1.0 is bitwise contrib — default lanes cannot move.
        with jax.named_scope("async_buffer"):
            arrive = fault_proc.arrival_score(slow, state.util.compute)
            arrive = jnp.where(contrib_mask > 0, arrive, jnp.inf)
            rank = jnp.argsort(jnp.argsort(arrive)).astype(jnp.float32)
            stale = jnp.floor(rank / jnp.maximum(pr.async_buffer, 1.0))
            stale_w = jnp.power(1.0 + stale, -pr.async_staleness_pow)
            agg_mask = contrib_mask * jnp.where(pr.plan_code == 1.0, stale_w,
                                                jnp.ones_like(stale_w))

        # ---- hierarchical (plan_code 2): edge FedAvg -> cloud mean ----
        # Client i reports to edge i % E (static shape, E = hierarchy_edges);
        # each edge computes the same weighted FedAvg the flat plan does over
        # its own group, and the cloud takes the unweighted mean over LIVE
        # edges (edges whose contributor weight is nonzero).  Always lowered,
        # selected away by a where on non-hier lanes — the fault engine's
        # branch-free pattern.
        with jax.named_scope("hier_aggregate"):
            n_edges = max(int(fl.hierarchy_edges), 1)
            edge_id = jnp.arange(n_clients) % n_edges
            w_cli = (agg_mask * state.util.data_size).astype(jnp.float32)
            edge_w = jnp.zeros((n_edges,), jnp.float32).at[edge_id].add(w_cli)
            edge_live = (edge_w > 0).astype(jnp.float32)
            n_live = jnp.maximum(jnp.sum(edge_live), 1.0)
            is_hier = pr.plan_code == 2.0

            def _hier_agg(d):
                df = d.astype(jnp.float32)
                wb = w_cli.reshape((-1,) + (1,) * (df.ndim - 1))
                esum = jnp.zeros((n_edges,) + df.shape[1:],
                                 jnp.float32).at[edge_id].add(df * wb)
                tail = (1,) * (df.ndim - 1)
                edelta = esum / jnp.maximum(edge_w, 1e-9).reshape((-1,) + tail)
                live = edge_live.reshape((-1,) + tail)
                return jnp.sum(edelta * live, axis=0) / n_live

        # ---- aggregation + server update (line 18) ----
        with jax.named_scope("aggregate"):
            agg_delta = agg.aggregate_stacked(deltas, agg_mask,
                                              state.util.data_size)
            agg_delta = jax.tree.map(
                lambda flat, d: jnp.where(is_hier, _hier_agg(d), flat),
                agg_delta, deltas)
            new_params, new_server_state = agg.apply_server_update(
                server, state.params, state.server_opt_state, agg_delta
            )
            new_params, new_server_state = _gate_server_update(
                update_gate, new_params, new_server_state, state)

        # ---- update-coherence (data-quality observable): cos(Δ_i, Δ_agg) ----
        def _dot(a, b):
            return sum(jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32))
                       for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

        agg_norm = jnp.sqrt(jnp.maximum(_dot(agg_delta, agg_delta), 1e-18))

        def _coh(delta_i):
            num = sum(
                jnp.sum(d.astype(jnp.float32) * g.astype(jnp.float32))
                for d, g in zip(jax.tree.leaves(delta_i), jax.tree.leaves(agg_delta))
            )
            nrm = jnp.sqrt(jnp.maximum(_dot(delta_i, delta_i), 1e-18))
            return num / (nrm * agg_norm)

        if fl.coherence_scoring:
            coherence = jax.vmap(_coh)(deltas) * contrib_mask
        else:
            coherence = None

        # ---- bookkeeping ----
        sel_denom = jnp.maximum(jnp.sum(contrib_mask), 1.0)
        global_loss = jnp.sum(post_loss * contrib_mask) / sel_denom
        failed_f = failed.astype(jnp.float32)
        util = sel_lib.update_utility_state(state.util, contrib_mask, pre_loss,
                                            post_loss, fl, coherence=coherence,
                                            attempted=sel_mask, failed=failed_f)
        kctl = sel_lib.update_k(state.kctl, global_loss, fl,
                                tol=pr.k_tol, patience=pr.k_patience)

        new_state = RoundState(new_params, new_server_state, util, kctl,
                               state.round_idx + 1, rng, new_fault)
        metrics = RoundMetrics(sel_mask, avail, failed_f,
                               pre_loss, post_loss, global_loss, k_eff, norms,
                               slow)
        return new_state, metrics

    return round_step


# ---------------------------------------------------------------------------
# client_cohort plan (population scale: train the gathered cohort only)
# ---------------------------------------------------------------------------


class CohortMetrics(NamedTuple):
    """Round metrics in cohort form: ``[k_max]``-shaped where
    :class:`RoundMetrics` was ``[n_clients]``-shaped.  At 10^5+ clients
    the dense form would emit O(N) per round; the driver only ever needs
    the cohort rows plus population scalars."""

    cohort_idx: jnp.ndarray    # [k_max] i32 selected client ids
    take: jnp.ndarray          # [k_max] f32 live-slot mask (rank < k_eff)
    failed: jnp.ndarray        # [k_max] f32 failure indicator (cohort)
    slow: jnp.ndarray          # [k_max] f32 straggler stretch (cohort)
    pre_loss: jnp.ndarray      # [k_max]
    post_loss: jnp.ndarray     # [k_max]
    global_loss: jnp.ndarray
    k_effective: jnp.ndarray
    update_norms: jnp.ndarray  # [k_max]
    fail_frac: jnp.ndarray     # population-wide failure fraction


def make_cohort_round(loss_fn: Callable, fl: FLConfig, n_clients: int,
                      sample_fn: Callable,
                      ckpt_every_steps: int = 2,
                      dp_use_kernel: Optional[bool] = None,
                      grad_accum: int = 1,
                      sel_chunks: int = 1):
    """Build the population-scale round:
    ``round_step(state, pop, data_key, params=None, update_gate=None) ->
    (state, CohortMetrics)``.

    Same Algorithm-1 semantics as :func:`make_parallel_round`, restructured
    so per-round COMPUTE is O(k_max) while only O(N) *vector* work touches
    the full population (DESIGN.md §7, ARCHITECTURE.md §Scale):

    1. availability, utility scores and the failure processes evaluate as
       [N] vector ops (shardable over the ``client`` mesh axis);
    2. :func:`~repro.core.selection.cohort_topk` picks the ceil(k_eff)
       cohort ON DEVICE from the (sharded) scores — ``sel_chunks`` is the
       auto-chunking policy's knob (``core/scale.py``), bitwise-neutral;
    3. ``sample_fn(key, pop, cohort_idx)`` gathers ONLY the cohort's data
       (the driver closes it over
       :func:`repro.data.synthetic.sample_cohort_batches`);
    4. local training / DP / aggregation run over the k_max cohort slots;
    5. per-client carries (utility EMAs, ``fail_ema``, FaultState) update
       via scatters back into the [N] state — the same
       ``update_utility_state`` rule the dense plans use.

    ``fl.k_max`` must be a positive static (it sizes the cohort): the
    dense plans' ``0 -> n_clients`` default would defeat the point at
    population scale, so it is rejected loudly.  DP noise keys are
    ``fold_in(k_dp, client_id)`` — a stable per-client stream independent
    of cohort composition.
    """
    score_fn = sel_lib.get_score_fn(fl.selection)
    local_train = _local_train_fn(loss_fn, fl, grad_accum)
    if not fl.k_max or int(fl.k_max) <= 0:
        raise ValueError(
            "the client_cohort plan needs an explicit positive FLConfig."
            "k_max (it is the static cohort size gathered to the compute "
            "lanes); the dense default 0 -> n_clients would train the "
            "whole population")
    k_max = int(fl.k_max)
    local_steps = int(fl.local_epochs)
    default_params = fl_params(fl)

    def round_step(state: RoundState, pop, data_key,
                   params: Optional[FLParams] = None,
                   update_gate=None) -> Tuple[RoundState, CohortMetrics]:
        pr = default_params if params is None else params
        server = make_server_optimizer(fl.server_opt, pr.server_lr)
        rng, k_avail, k_sel, k_fail, k_dp = jax.random.split(state.rng, 5)

        # ---- O(N) population vector phase ----
        avail = jax.random.bernoulli(k_avail, pr.avail_prob,
                                     (n_clients,)).astype(jnp.float32)
        utility = sel_lib.compute_utility(state.util, fl,
                                          fault_w=pr.fault_util_w)
        k_eff = (state.kctl.k if fl.adaptive_k
                 else jnp.asarray(float(fl.clients_per_round), jnp.float32))
        k_eff = jnp.minimum(k_eff, float(k_max))
        scores = score_fn(k_sel, state.util, utility, avail,
                          pr.explore_noise)
        idx, take = sel_lib.cohort_topk(scores, avail, k_eff, k_max,
                                        chunks=sel_chunks)
        fail_at_full, slow_full, new_fault = fault_proc.fault_step(
            state.fault, k_fail, pr, n_clients, local_steps)

        # ---- cohort gather + O(k_max) training phase ----
        fail_at, slow = fault_proc.gather_cohort(fail_at_full, slow_full, idx)
        eff_steps, failed = _effective_steps(
            fail_at, local_steps, ckpt_every_steps, fl.fault_tolerance)
        batches = sample_fn(data_key, pop, idx)
        deltas, pre_loss, post_loss = jax.vmap(
            local_train, in_axes=(None, 0, 0, None)
        )(state.params, batches, eff_steps, pr.local_lr)

        if fl.dp_enabled:
            sigma = _dp_sigma(fl, pr)
            keys = jax.vmap(lambda c: jax.random.fold_in(k_dp, c))(idx)

            def privatize(d, k):
                return dp_lib.privatize_update(
                    d, k, mode=fl.dp_mode, clip=pr.dp_clip, sigma=sigma,
                    use_kernel=dp_use_kernel,
                )

            deltas, norms = jax.vmap(privatize)(deltas, keys)
        else:
            norms = jax.vmap(dp_lib.global_norm)(deltas)

        contrib = take * (eff_steps > 0)
        agg_delta = agg.aggregate_stacked(deltas, contrib,
                                          state.util.data_size[idx])
        new_params, new_server_state = agg.apply_server_update(
            server, state.params, state.server_opt_state, agg_delta)
        new_params, new_server_state = _gate_server_update(
            update_gate, new_params, new_server_state, state)

        if fl.coherence_scoring:
            def _dot(a, b):
                return sum(
                    jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32))
                    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

            agg_norm = jnp.sqrt(jnp.maximum(_dot(agg_delta, agg_delta),
                                            1e-18))

            def _coh(delta_i):
                num = sum(
                    jnp.sum(d.astype(jnp.float32) * g.astype(jnp.float32))
                    for d, g in zip(jax.tree.leaves(delta_i),
                                    jax.tree.leaves(agg_delta)))
                nrm = jnp.sqrt(jnp.maximum(_dot(delta_i, delta_i), 1e-18))
                return num / (nrm * agg_norm)

            coherence_c = jax.vmap(_coh)(deltas) * contrib
        else:
            coherence_c = None

        # ---- scatter back into the [N] carries ----
        def scatter(vals_c):
            return jnp.zeros((n_clients,), jnp.float32).at[idx].add(vals_c)

        sel_full = scatter(take)
        contrib_full = scatter(contrib)
        failed_f = failed.astype(jnp.float32)
        sel_denom = jnp.maximum(jnp.sum(contrib), 1.0)
        global_loss = jnp.sum(post_loss * contrib) / sel_denom
        util = sel_lib.update_utility_state(
            state.util, contrib_full,
            scatter(pre_loss * contrib), scatter(post_loss * contrib), fl,
            coherence=None if coherence_c is None else scatter(coherence_c),
            attempted=sel_full, failed=scatter(failed_f * take))
        kctl = sel_lib.update_k(state.kctl, global_loss, fl,
                                tol=pr.k_tol, patience=pr.k_patience)

        new_state = RoundState(new_params, new_server_state, util, kctl,
                               state.round_idx + 1, rng, new_fault)
        metrics = CohortMetrics(
            cohort_idx=idx, take=take, failed=failed_f * take,
            slow=slow, pre_loss=pre_loss, post_loss=post_loss,
            global_loss=global_loss, k_effective=k_eff, update_norms=norms,
            fail_frac=jnp.mean((fail_at_full < local_steps)
                               .astype(jnp.float32)))
        return new_state, metrics

    return round_step


# ---------------------------------------------------------------------------
# client_serial plan (for >=8B models; whole mesh per client)
# ---------------------------------------------------------------------------


def make_serial_round(loss_fn: Callable, fl: FLConfig, n_clients: int,
                      ckpt_every_steps: int = 2,
                      dp_use_kernel: Optional[bool] = None, grad_accum: int = 1,
                      delta_dtype=None):
    """Build ``round_step(state, batches, params=None) -> (state, metrics)``.

    batches leaves: [K, local_steps, ...] — data for the K client slots that
    the host-side driver filled with the selected clients' shards (the
    in-step selection produces the slot→client mapping used for weighting).
    K = fl.serial_clients_in_step is static.  ``ckpt_every_steps`` is the
    same checkpoint interval the parallel plan takes (it used to be
    hardcoded to 2 here, so a configured interval silently only applied to
    the parallel plan).  ``params``/``update_gate``: runtime
    :class:`FLParams` and the budget-exhaustion mask, as in
    :func:`make_parallel_round`.
    """
    strategy = sel_lib.get_strategy(fl.selection)
    local_train = _local_train_fn(loss_fn, fl, grad_accum)
    K = fl.serial_clients_in_step
    k_max = int(fl.k_max or n_clients)
    default_params = fl_params(fl)

    def round_step(state: RoundState, batches,
                   params: Optional[FLParams] = None,
                   update_gate=None) -> Tuple[RoundState, RoundMetrics]:
        pr = default_params if params is None else params
        server = make_server_optimizer(fl.server_opt, pr.server_lr)
        sigma = _dp_sigma(fl, pr) if fl.dp_enabled else 0.0
        rng, k_avail, k_sel, k_fail, k_dp = jax.random.split(state.rng, 5)
        avail = jax.random.bernoulli(k_avail, pr.avail_prob,
                                     (n_clients,)).astype(jnp.float32)
        utility = sel_lib.compute_utility(state.util, fl,
                                          fault_w=pr.fault_util_w)
        k_eff = jnp.minimum(
            state.kctl.k if fl.adaptive_k else float(fl.clients_per_round), float(K)
        )
        sel_mask = strategy(k_sel, state.util, utility, avail, k_eff,
                            min(K, k_max), pr.explore_noise)
        # slot i <- i-th selected client (host driver feeds matching data)
        _, sel_idx = jax.lax.top_k(sel_mask + utility * 1e-6, K)
        slot_live = (jnp.arange(K) < k_eff).astype(jnp.float32)

        # the serial plan keeps the historical i.i.d. draw (per SLOT, so a
        # per-client process state cannot follow the slot→client remapping
        # across rounds); non-iid fault processes are a client_parallel
        # feature — DESIGN.md §6
        local_steps = jax.tree.leaves(batches)[0].shape[1]
        fail_at = fault_proc.iid_fail_times(
            k_fail, jax.random.fold_in(k_fail, 1), pr.failure_prob, K,
            local_steps)
        eff_steps, failed = _effective_steps(fail_at, local_steps,
                                             ckpt_every_steps,
                                             fl.fault_tolerance)

        def per_client(carry, xs):
            acc, pre_l, post_l, norms, slot = carry
            client_batches, e_steps, live = xs
            delta, pre, post = local_train(state.params, client_batches,
                                           e_steps, pr.local_lr)
            if fl.dp_enabled:
                delta, norm = dp_lib.privatize_update(
                    delta, jax.random.fold_in(k_dp, slot),
                    mode=fl.dp_mode, clip=pr.dp_clip, sigma=sigma,
                    use_kernel=dp_use_kernel,
                )
            else:
                norm = dp_lib.global_norm(delta)
            m = live * (e_steps > 0)
            acc = agg.stream_accumulate(acc, delta, m, 1.0)
            return (
                acc,
                pre_l.at[slot].set(pre),
                post_l.at[slot].set(post),
                norms.at[slot].set(norm),
                slot + 1,
            ), None

        acc0 = agg.stream_init(state.params, delta_dtype or jnp.float32)
        zK = jnp.zeros((K,), jnp.float32)
        (acc, pre_loss, post_loss, norms, _), _ = jax.lax.scan(
            per_client,
            (acc0, zK, zK, zK, jnp.zeros((), jnp.int32)),
            (batches, eff_steps, slot_live),
        )
        agg_delta = agg.stream_finalize(acc)
        new_params, new_server_state = agg.apply_server_update(
            server, state.params, state.server_opt_state, agg_delta
        )
        new_params, new_server_state = _gate_server_update(
            update_gate, new_params, new_server_state, state)

        contrib = slot_live * (eff_steps > 0)
        denom = jnp.maximum(jnp.sum(contrib), 1.0)
        global_loss = jnp.sum(post_loss * contrib) / denom
        # scatter slot losses back to the selected clients' utility entries
        full_mask = jnp.zeros((n_clients,), jnp.float32).at[sel_idx].add(contrib)
        full_pre = jnp.zeros((n_clients,), jnp.float32).at[sel_idx].add(pre_loss * contrib)
        full_post = jnp.zeros((n_clients,), jnp.float32).at[sel_idx].add(post_loss * contrib)
        util = sel_lib.update_utility_state(state.util, full_mask, full_pre, full_post, fl)
        kctl = sel_lib.update_k(state.kctl, global_loss, fl,
                                tol=pr.k_tol, patience=pr.k_patience)

        new_state = RoundState(new_params, new_server_state, util, kctl,
                               state.round_idx + 1, rng, state.fault)
        metrics = RoundMetrics(full_mask, avail, failed.astype(jnp.float32),
                               full_pre, full_post, global_loss, k_eff, norms,
                               jnp.ones((n_clients,), jnp.float32))
        return new_state, metrics

    return round_step
