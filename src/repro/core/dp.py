"""Differential privacy for FL model updates (paper §IV, "Incorporating
differential privacy in FL").

Two modes:

* ``paper``   — the mechanism exactly as written in the paper:
                ``∇w ← ∇w + N(0, σ²)`` with a fixed, user-chosen σ
                ("calibrated to the privacy budget ε" via
                :func:`gaussian_sigma` with the stated sensitivity).
                Note: without clipping the sensitivity is unbounded, so this
                is only (ε, δ)-DP under an *assumed* bound — we reproduce it
                faithfully and flag it.
* ``clipped`` — beyond-paper hardening: per-client L2 clipping of the whole
                update to S, then σ = S·sqrt(2 ln(1.25/δ))/ε (classic
                Gaussian mechanism), plus an RDP accountant for multi-round
                composition (client-level DP).

Both operate on arbitrary pytrees so every assigned architecture (dense →
400B MoE) is covered by the same code path.  The fused clip+noise Pallas
kernel in ``repro.kernels.dp_clip_noise`` implements the flat hot loop.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Calibration
# ---------------------------------------------------------------------------


def gaussian_sigma(epsilon: float, delta: float, sensitivity: float = 1.0) -> float:
    """Classic Gaussian-mechanism noise scale for one release."""
    if epsilon <= 0:
        raise ValueError("epsilon must be > 0")
    return sensitivity * math.sqrt(2.0 * math.log(1.25 / delta)) / epsilon


def gaussian_sigma_rt(epsilon, delta: float, sensitivity=1.0):
    """Trace-safe :func:`gaussian_sigma`: ``epsilon``/``sensitivity`` may be
    traced jnp scalars (runtime FLParams inside a compiled round step);
    ``delta`` stays a static Python float so the log/sqrt fold on the host.
    No validation — callers own the ε > 0 contract."""
    return sensitivity * (math.sqrt(2.0 * math.log(1.25 / delta)) / epsilon)


# ---------------------------------------------------------------------------
# Pytree mechanics
# ---------------------------------------------------------------------------


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def clip_by_global_norm(tree, clip: float):
    """Scale the whole update so its global L2 norm is <= clip."""
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, clip / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), tree), norm


def add_gaussian_noise(tree, sigma: float, key):
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    noised = [
        (x + sigma * jax.random.normal(k, x.shape, jnp.float32).astype(jnp.float32)).astype(
            x.dtype
        )
        for x, k in zip(leaves, keys)
    ]
    return jax.tree.unflatten(treedef, noised)


def privatize_update(tree, key, *, mode: str, clip: float, sigma: float,
                     use_kernel: Optional[bool] = None):
    """Apply the paper's DP step to one client's update pytree.

    ``use_kernel=None`` auto-routes the clipped mechanism: the fused Pallas
    clip+noise kernel when a TPU backend is attached, the jnp reference path
    on CPU (``kernels.ref.dp_clip_noise_tree_ref`` semantics — same noise
    keys, so the routing is observationally neutral).

    Returns (noised_update, pre_clip_norm).
    """
    if mode == "paper":
        norm = global_norm(tree)
        return add_gaussian_noise(tree, sigma, key), norm
    if mode == "clipped":
        from repro.kernels import ops as kops

        if use_kernel is None:
            use_kernel = kops.pallas_backend_ready()
        if use_kernel:
            return kops.dp_clip_noise_tree(tree, key, clip, sigma)
        clipped, norm = clip_by_global_norm(tree, clip)
        return add_gaussian_noise(clipped, sigma, key), norm
    raise ValueError(mode)


# ---------------------------------------------------------------------------
# RDP accountant — moved to repro.privacy.accountant (PR 3); re-exported
# here so existing call sites (`dp_lib.RdpAccountant`, ...) keep working.
# ---------------------------------------------------------------------------

from repro.privacy.accountant import (ORDERS as _ORDERS,  # noqa: E402,F401
                                      RdpAccountant, compose_epsilon,
                                      noise_multiplier_for_budget,
                                      rdp_gaussian, rdp_subsampled_gaussian,
                                      rdp_to_dp)
