"""Population-scale memory accounting + auto-chunking (DESIGN.md §7).

The population engine (``train/fl_driver.run_fl_population``) keeps every
per-client quantity as an ``[n_clients]`` (or ``[n_clients, m]``) array:
the lazy membership table of the :class:`~repro.data.synthetic.Population`,
the :class:`~repro.core.selection.UtilityState` /
:class:`~repro.fault.process.FaultState` carries, and the transient
score/noise buffers cohort selection allocates each round.  This module is
the budget those arrays are held to — the DESIGN.md §7 accounting formulas
as code, so tests can assert them against XLA's measured buffer sizes
(``jax.jit(...).lower().compile().memory_analysis()``) and the driver can
derive an auto-chunking policy instead of hoping a population fits.

Accounting (bytes, per lane unless noted):

* **Resident population data** (:func:`population_data_bytes`) — the
  membership table ``member_idx [N, m] i32`` + per-client scalars
  (``member_size`` i32, ``data_size``/``data_quality`` f32): shared by
  every lane (replicated over ``lane``, sharded over ``client``).
* **Per-lane carries** (:func:`population_carry_bytes`) — the 11
  ``UtilityState`` + 2 ``FaultState`` f32 ``[N]`` vectors that ride the
  round scan.
* **Selection transients** (:func:`selection_transient_bytes`) — the f32
  ``[N]``-shaped temporaries one cohort-selection pass materialises
  (scores, availability-masked scores, exploration noise, availability)
  — the only term chunking shrinks: with ``c`` chunks the working set is
  ``⌈N/c⌉``-shaped.
* **Cohort batches** (:func:`cohort_batch_bytes`) — the gathered
  ``[k_max, steps, batch, d]`` training data; independent of N, which is
  what makes the whole plan sublinear.

Policy (:func:`auto_chunks`): chunk the SELECTION scan — never the
carries, which must persist across rounds regardless — so its transient
working set fits the per-device budget left after the resident arrays.
Chunked and unchunked selection are bitwise identical
(:func:`repro.core.selection.cohort_topk`; pinned in tests/test_scale.py),
so the policy is pure memory shaping, not semantics.
"""
from __future__ import annotations

import math

# Per-client f32 vectors carried across rounds: 11 UtilityState fields
# (core/selection.py) + 2 FaultState fields (fault/process.py).  A test
# pins these against the real NamedTuples so the accounting cannot rot.
UTILITY_STATE_FIELDS = 11
FAULT_STATE_FIELDS = 2
CARRY_FIELDS = UTILITY_STATE_FIELDS + FAULT_STATE_FIELDS

# f32 [N]-shaped temporaries one unchunked cohort-selection pass holds
# live at once: scores, availability-masked scores, exploration noise,
# availability mask.
SELECTION_BUFFERS = 4

_F32 = 4
_I32 = 4


def population_data_bytes(n_clients: int, members_per_client: int) -> int:
    """Resident bytes of a Population's per-client arrays (pool excluded —
    it is O(pool) and shared, not O(N)): ``member_idx [N, m] i32`` +
    ``member_size [N] i32`` + ``data_size``/``data_quality [N] f32``."""
    return n_clients * (members_per_client * _I32 + _I32 + 2 * _F32)


def population_carry_bytes(n_clients: int) -> int:
    """Per-lane scan-carry bytes of the per-client state vectors."""
    return n_clients * CARRY_FIELDS * _F32


def selection_transient_bytes(n_clients: int, chunks: int = 1) -> int:
    """Peak f32 transient bytes of one cohort-selection pass with the
    score scan split into ``chunks`` pieces."""
    per_chunk = -(-n_clients // max(int(chunks), 1))
    return SELECTION_BUFFERS * per_chunk * _F32


def plan_transient_buffers(plan: str) -> int:
    """Extra per-round [n] f32 transients a registered execution plan adds
    on top of the selection pass, read off the core/plans registry: plans
    flagged ``fault_arrivals`` (buffered_async) materialise an arrival-score
    and an arrival-rank vector to order updates.  Memory accounting routes
    through the registry so a new plan extends the budget model by
    registering, not by editing this module."""
    from repro.core.plans import get_plan  # lazy: scale stays import-light
    return 2 if get_plan(plan).fault_arrivals else 0


def cohort_batch_bytes(k_max: int, local_steps: int, batch: int,
                       n_features: int) -> int:
    """Bytes of one round's gathered cohort batches (x f32 + y i32) —
    the term that does NOT grow with N."""
    return k_max * local_steps * batch * (n_features * _F32 + _I32)


# Replicated model-parameter budget (bytes, per lane).  A detector whose
# ``ModelSpec.param_bytes()`` stays under this replicates across the scale
# mesh like the PR 6 design assumed ("the detectors are tiny relative to
# the population state"); above it the driver installs the
# RULES_MODEL_SCALE sharding context so the spec's declared ``param_axes``
# tensor-parallel over the ``client`` axis.  The default is deliberately
# generous for the builtin zoo (all ≤ ~100 KiB — they replicate); override
# per call (``run_fl_population(model_replicated_max_bytes=...)``) to force
# the sharded program, as the parity test does.
MODEL_REPLICATED_MAX_BYTES = 4 << 20


def model_needs_sharding(param_bytes: int,
                         max_bytes: int | None = None) -> bool:
    """True when a model's replicated parameter footprint exceeds the
    replicated-size budget and its parameters should shard via the
    ``ModelSpec.param_axes`` hook."""
    budget = MODEL_REPLICATED_MAX_BYTES if max_bytes is None else max_bytes
    return param_bytes > budget


def population_resident_bytes(n_clients: int, members_per_client: int,
                              n_lanes: int = 1, model_bytes: int = 0) -> int:
    """Everything that must stay resident per device (data shared across
    lanes + one carry per lane + one model replica per lane — pass the
    spec's ``param_bytes()`` as ``model_bytes``; 0 keeps the pre-model
    accounting for callers that only budget the population state)."""
    return (population_data_bytes(n_clients, members_per_client)
            + n_lanes * population_carry_bytes(n_clients)
            + n_lanes * model_bytes)


def auto_chunks(n_clients: int, budget_bytes: int,
                members_per_client: int, n_lanes: int = 1,
                model_bytes: int = 0) -> int:
    """Selection-chunk count that fits ``budget_bytes`` per device.

    The resident arrays (membership + carries + model replicas) are
    irreducible — if they alone overflow the budget this raises, because
    no chunking policy can fix a population whose *state* does not fit
    (shard the client axis over more devices instead).  Otherwise the
    selection transients are chunked into whatever budget remains,
    floored at one chunk.
    """
    resident = population_resident_bytes(n_clients, members_per_client,
                                         n_lanes, model_bytes)
    if resident >= budget_bytes:
        raise ValueError(
            f"population resident state ({resident} B) exceeds the "
            f"per-device budget ({budget_bytes} B): {n_clients} clients x "
            f"{members_per_client} members x {n_lanes} lanes cannot fit "
            "regardless of chunking — shard the client axis over more "
            "devices or shrink the population")
    free = budget_bytes - resident
    transient = selection_transient_bytes(n_clients, 1)
    return max(1, math.ceil(transient / max(free, 1)))
