"""Encoder-decoder backbone (Seamless-M4T-style, audio use case).

Per the assignment carve-out the audio frontend (mel-spectrogram + conv
feature extractor) is a STUB: the encoder consumes precomputed frame
embeddings [B, T_enc, d].  The encoder is a bidirectional transformer; the
decoder is a causal transformer with per-layer cross-attention whose K/V are
projected once from the encoder output and carried in the decode cache.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import layers as L
from repro.models.shardctx import constrain
from repro.models.sharding import add_axis, pm, split_meta
from repro.models.transformer import padded_vocab


def _enc_block_init(key, cfg):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "ln1": L.init_rmsnorm(k1, cfg.d_model, cfg),
        "attn": attn_lib.init_attention(k2, cfg),
        "ln2": L.init_rmsnorm(k3, cfg.d_model, cfg),
        "mlp": L.init_mlp(k4, cfg),
    }


def _dec_block_init(key, cfg):
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    return {
        "ln1": L.init_rmsnorm(k1, cfg.d_model, cfg),
        "self_attn": attn_lib.init_attention(k2, cfg),
        "lnx": L.init_rmsnorm(k3, cfg.d_model, cfg),
        "cross_attn": attn_lib.init_attention(k4, cfg),
        "ln2": L.init_rmsnorm(k5, cfg.d_model, cfg),
        "mlp": L.init_mlp(k6, cfg),
    }


def init_encdec_meta(key, cfg):
    ke, kenc, kdec, kn1, kn2, kh = jax.random.split(key, 6)
    pv = padded_vocab(cfg)
    enc_stack = jax.vmap(lambda k: _enc_block_init(k, cfg))(
        jax.random.split(kenc, cfg.enc_layers)
    )
    dec_stack = jax.vmap(lambda k: _dec_block_init(k, cfg))(
        jax.random.split(kdec, cfg.n_layers)
    )
    meta: Dict[str, Any] = {
        "embed": {
            "table": pm(
                L.normal_init(ke, (pv, cfg.d_model), jnp.dtype(cfg.dtype), 0.02),
                "vocab", "embed",
            )
        },
        "enc_stack": add_axis(enc_stack, "layers"),
        "enc_ln": L.init_rmsnorm(kn1, cfg.d_model, cfg),
        "dec_stack": add_axis(dec_stack, "layers"),
        "final_ln": L.init_rmsnorm(kn2, cfg.d_model, cfg),
        "head": {
            "w": pm(
                L.normal_init(kh, (cfg.d_model, pv), jnp.dtype(cfg.dtype), 0.02),
                "embed", "vocab",
            )
        },
    }
    return meta


def init_encdec(key, cfg):
    return split_meta(init_encdec_meta(key, cfg))


def encdec_axes(cfg):
    meta = jax.eval_shape(lambda k: init_encdec_meta(k, cfg), jax.random.key(0))
    return split_meta(meta)[1]


def encode(params, cfg, enc_embeds, *, remat: str = "full"):
    """enc_embeds: [B, T, d] stub-frontend frame embeddings -> [B, T, d]."""
    x = enc_embeds.astype(jnp.dtype(cfg.dtype))
    t = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), x.shape[:1] + (t,))
    x = constrain(x, "act_batch", "act_seq", None)

    def body(carry, pl):
        h = carry
        a = attn_lib.encoder_attention(
            pl["attn"], L.rmsnorm(pl["ln1"], h, cfg.norm_eps), positions, cfg
        )
        h = h + a
        h = h + L.mlp(pl["mlp"], L.rmsnorm(pl["ln2"], h, cfg.norm_eps), cfg.act)
        h = constrain(h, "act_batch", "act_seq", None)
        return h, None

    if remat != "none":
        body = jax.checkpoint(body, prevent_cse=True)
    x, _ = jax.lax.scan(body, x, params["enc_stack"])
    return L.rmsnorm(params["enc_ln"], x, cfg.norm_eps)


def decode_train(params, cfg, tokens, enc_out, *, remat: str = "full", window=None,
                 last_only: bool = False):
    """Teacher-forced decoder pass.  Returns logits [B, S, V]."""
    x = L.embed(params["embed"], tokens)
    s = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), x.shape[:1] + (s,))
    x = constrain(x, "act_batch", "act_seq", None)

    def body(carry, pl):
        h = carry
        a = attn_lib.attention(
            pl["self_attn"], L.rmsnorm(pl["ln1"], h, cfg.norm_eps), positions, cfg,
            window=window,
        )
        h = h + a
        enc_kv = attn_lib.project_enc_kv(pl["cross_attn"], enc_out, cfg)
        c = attn_lib.cross_attention(
            pl["cross_attn"], L.rmsnorm(pl["lnx"], h, cfg.norm_eps), enc_kv, cfg
        )
        h = h + c
        h = h + L.mlp(pl["mlp"], L.rmsnorm(pl["ln2"], h, cfg.norm_eps), cfg.act)
        h = constrain(h, "act_batch", "act_seq", None)
        return h, None

    if remat != "none":
        body = jax.checkpoint(body, prevent_cse=True)
    x, _ = jax.lax.scan(body, x, params["dec_stack"])
    if last_only:
        x = x[:, -1:]
    x = L.rmsnorm(params["final_ln"], x, cfg.norm_eps)
    logits = jnp.einsum(
        "bsd,dv->bsv", x.astype(jnp.float32), params["head"]["w"].astype(jnp.float32)
    )
    return _mask_pad(logits, cfg)


def _mask_pad(logits, cfg):
    pv, v = logits.shape[-1], cfg.vocab_size
    if pv != v:
        neg = jnp.full(logits.shape[:-1] + (pv - v,), -1e30, logits.dtype)
        logits = jnp.concatenate([logits[..., :v], neg], axis=-1)
    return logits


def encdec_forward(params, cfg, enc_embeds, tokens, *, remat="full", window=None,
                   last_only=False):
    enc_out = encode(params, cfg, enc_embeds, remat=remat)
    logits = decode_train(params, cfg, tokens, enc_out, remat=remat, window=window,
                          last_only=last_only)
    return logits, jnp.zeros((), jnp.float32)


def encdec_loss(params, cfg, enc_embeds, tokens, labels, *, remat="full"):
    logits, _ = encdec_forward(params, cfg, enc_embeds, tokens, remat=remat)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def init_decode_cache(params, cfg, batch: int, cache_len: int, enc_out=None, window=None):
    """Self-attn rolling/full cache + cross-attn K/V projected from enc_out.

    When enc_out is None (dry-run input_specs) callers build the same pytree
    from ShapeDtypeStructs instead.
    """
    clen = min(cache_len, window) if window else cache_len
    self_cache = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape).copy(),
        attn_lib.init_cache(cfg, batch, clen),
    )

    def per_layer_kv(pl):
        k, v = attn_lib.project_enc_kv(pl["cross_attn"], enc_out, cfg)
        return {"k": k, "v": v}

    cross = jax.vmap(per_layer_kv, in_axes=(0,))(params["dec_stack"])
    return {"self": self_cache, "cross": cross}


def encdec_decode_step(params, cfg, token, caches, index, *, window=None):
    """One-token decode.  token: [B,1].  Returns (logits, new_caches)."""
    x = L.embed(params["embed"], token)
    positions = jnp.broadcast_to(index.astype(jnp.int32), token.shape)

    def body(carry, xs):
        h = carry
        pl, self_c, cross_c = xs
        a, new_self = attn_lib.decode_attention(
            pl["self_attn"], L.rmsnorm(pl["ln1"], h, cfg.norm_eps), self_c, index,
            positions, cfg, window=window,
        )
        h = h + a
        c = attn_lib.cross_attention(
            pl["cross_attn"],
            L.rmsnorm(pl["lnx"], h, cfg.norm_eps),
            (cross_c["k"], cross_c["v"]),
            cfg,
        )
        h = h + c
        h = h + L.mlp(pl["mlp"], L.rmsnorm(pl["ln2"], h, cfg.norm_eps), cfg.act)
        return h, new_self

    x, new_self = jax.lax.scan(body, x, (params["dec_stack"], caches["self"], caches["cross"]))
    x = L.rmsnorm(params["final_ln"], x, cfg.norm_eps)
    logits = jnp.einsum(
        "bsd,dv->bsv", x.astype(jnp.float32), params["head"]["w"].astype(jnp.float32)
    )
    return _mask_pad(logits, cfg), {"self": new_self, "cross": caches["cross"]}
