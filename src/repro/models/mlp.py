"""The paper's own detector model: a feed-forward network for tabular
network-traffic features (Marfo et al. 2022, ref [1] of the paper).

Binary/multiclass anomaly detector: d_in -> hidden -> hidden/2 -> n_classes
with ReLU + dropout-free deterministic eval (FL rounds are short; the paper
reports no dropout).  Kept deliberately simple & faithful — the large
assigned architectures exercise the framework's scale path instead.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.sharding import pm, split_meta


def init_mlp_meta(key, d_in: int, hidden: int, n_classes: int):
    k1, k2, k3 = jax.random.split(key, 3)

    def lin(k, a, b):
        w = jax.random.normal(k, (a, b), jnp.float32) / jnp.sqrt(a)
        return {"w": pm(w, "embed", "mlp"), "b": pm(jnp.zeros((b,), jnp.float32), "mlp")}

    return {
        "l1": lin(k1, d_in, hidden),
        "l2": lin(k2, hidden, hidden // 2),
        "out": lin(k3, hidden // 2, n_classes),
    }


def init_mlp(key, d_in: int, hidden: int = 128, n_classes: int = 2):
    return split_meta(init_mlp_meta(key, d_in, hidden, n_classes))[0]


def mlp_logits(params, x):
    h = jax.nn.relu(x @ params["l1"]["w"] + params["l1"]["b"])
    h = jax.nn.relu(h @ params["l2"]["w"] + params["l2"]["b"])
    return h @ params["out"]["w"] + params["out"]["b"]


def mlp_loss(params, batch):
    """batch: {"x": [b, d], "y": [b] int32} -> mean CE."""
    logits = mlp_logits(params, batch["x"])
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["y"][:, None], axis=-1)[:, 0]
    return jnp.mean(lse - gold)


def mlp_predict_proba(params, x):
    return jax.nn.softmax(mlp_logits(params, x), axis=-1)


def accuracy(params, x, y) -> jnp.ndarray:
    return jnp.mean((jnp.argmax(mlp_logits(params, x), axis=-1) == y).astype(jnp.float32))


def auc_roc_jnp(scores, labels) -> jnp.ndarray:
    """jit-safe rank AUC (Mann-Whitney U normalisation) — traceable inside
    ``lax.scan``, so the compiled engine can emit AUC history without host
    round-trips.  No average-rank tie correction: scores are continuous
    softmax outputs, so ties have measure zero (``auc_roc`` below remains the
    tie-exact host oracle)."""
    s = scores.astype(jnp.float32)
    y = labels.astype(jnp.float32)
    n_pos = jnp.sum(y)
    n_neg = y.shape[0] - n_pos
    order = jnp.argsort(s)
    ranks = jnp.zeros_like(s).at[order].set(
        jnp.arange(1, s.shape[0] + 1, dtype=jnp.float32)
    )
    u = jnp.sum(ranks * y) - n_pos * (n_pos + 1.0) / 2.0
    return u / jnp.maximum(n_pos * n_neg, 1.0)


def auc_roc(scores, labels) -> float:
    """Rank-based AUC-ROC (equivalent to the Mann-Whitney U statistic
    normalisation) — no sklearn in this environment."""
    import numpy as np

    s = np.asarray(scores, dtype=np.float64)
    y = np.asarray(labels)
    pos = s[y == 1]
    neg = s[y == 0]
    if len(pos) == 0 or len(neg) == 0:
        return float("nan")
    order = np.argsort(np.concatenate([neg, pos]), kind="mergesort")
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(1, len(order) + 1)
    # average ranks for ties
    allv = np.concatenate([neg, pos])
    sorted_v = allv[order]
    i = 0
    while i < len(sorted_v):
        j = i
        while j + 1 < len(sorted_v) and sorted_v[j + 1] == sorted_v[i]:
            j += 1
        if j > i:
            ranks[order[i : j + 1]] = np.mean(ranks[order[i : j + 1]])
        i = j + 1
    r_pos = ranks[len(neg):].sum()
    u = r_pos - len(pos) * (len(pos) + 1) / 2.0
    return float(u / (len(pos) * len(neg)))
