"""Logical-axis sharding: params carry logical axis names, a rule table maps
them to mesh axes (MaxText-style), and helpers convert whole pytrees into
``PartitionSpec`` trees for ``jax.jit`` in/out shardings.

Logical axes used by the model zoo:
  "embed"    d_model dimension of weight matrices (FSDP candidate)
  "mlp"      d_ff dimension                      (tensor parallel)
  "heads"    query-head dimension                (tensor parallel)
  "kv"       kv-head dimension (may be < mesh model size -> replicated)
  "vocab"    vocabulary dimension                (tensor parallel)
  "experts"  MoE expert dimension                (expert parallel)
  "layers"   stacked-scan layer dimension        (never sharded)
  "act_batch"  activation batch                  (data parallel)
  "act_seq"    activation sequence               (context parallel, decode KV)
  None       replicated
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class ParamMeta:
    """A parameter value bundled with its logical axis names.

    Registered as a pytree node whose only child is ``value`` and whose
    ``axes`` are static aux-data, so ``vmap`` / ``eval_shape`` / ``scan``
    transparently batch the value while preserving the logical axes.
    """

    __slots__ = ("value", "axes")

    def __init__(self, value, axes):
        self.value = value
        self.axes = tuple(axes)

    def __repr__(self):
        shape = getattr(self.value, "shape", None)
        return f"ParamMeta({shape}, axes={self.axes})"


jax.tree_util.register_pytree_node(
    ParamMeta,
    lambda m: ((m.value,), m.axes),
    lambda axes, children: ParamMeta(children[0], axes),
)


def pm(value, *axes):
    assert value.ndim == len(axes), (value.shape, axes)
    return ParamMeta(value, tuple(axes))


def is_meta(x) -> bool:
    return isinstance(x, ParamMeta)


def split_meta(tree):
    """Split a pytree of ParamMeta into (values, logical_axes) pytrees."""
    vals = jax.tree.map(lambda m: m.value, tree, is_leaf=is_meta)
    axes = jax.tree.map(lambda m: m.axes, tree, is_leaf=is_meta)
    return vals, axes


def add_axis(meta_tree, name: str = "layers"):
    """Prepend a stacked (scan) axis to every ParamMeta in a tree."""
    return jax.tree.map(
        lambda m: ParamMeta(m.value, (name,) + m.axes), meta_tree, is_leaf=is_meta
    )


# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------

# client_serial plan: the whole mesh co-trains one client -> FSDP over data.
RULES_SERIAL = {
    "embed": ("data",),
    "mlp": ("model",),
    "heads": ("model",),
    "kv": None,
    "vocab": ("model",),
    "experts": ("model",),
    "layers": None,
    "act_batch": ("data",),
    # sequence parallelism is an opt-in override (EXPERIMENTS.md §Perf A1) —
    # None keeps the residual stream replicated across the model axis
    "act_seq": None,
    "ssm_state": None,
}

# client_parallel plan: clients live on the data axis -> per-client weights
# must NOT be sharded over data (they diverge per client).
RULES_PARALLEL = {
    "embed": None,
    "mlp": ("model",),
    "heads": ("model",),
    "kv": None,
    "vocab": ("model",),
    "experts": ("model",),
    "layers": None,
    "act_batch": ("data",),
    "act_seq": None,
    "ssm_state": None,
}


def with_pod(rules: dict, multi_pod: bool, family: str) -> dict:
    """Extend a rule table with the 'pod' axis for the 2x16x16 mesh.

    client_serial: pod joins the FSDP/data-parallel group (one giant client
    mesh).  client_parallel: pod multiplies the client axis, so activations
    shard over (pod, data) while weights stay unsharded over both.
    """
    if not multi_pod:
        return rules
    r = dict(rules)
    if family == "client_serial":
        if r["embed"]:
            r["embed"] = ("pod", "data")
        r["act_batch"] = ("pod", "data")
    else:
        r["act_batch"] = ("pod", "data")
    return r


def make_rules(plan: str, multi_pod: bool) -> dict:
    """Sharding rules for a registered plan — keyed on the plan's STATIC
    program family (core/plans.py), so same-family plans (buffered_async /
    hierarchical ride client_parallel) share one rule table."""
    from repro.core.plans import plan_family  # lazy: keep this module light
    family = plan_family(plan)
    base = RULES_SERIAL if family == "client_serial" else RULES_PARALLEL
    return with_pod(base, multi_pod, family)


# ---------------------------------------------------------------------------
# Population engine (ISSUE 6): the 2-D lane × client scale mesh
# ---------------------------------------------------------------------------

# client_cohort plan over launch/mesh.py::make_scale_mesh — logical axes:
#   "clients"  the population axis of every per-client [N] array
#   "lanes"    the sweep's seed×config trial axis
# Model params replicate (the detectors are tiny relative to the
# population state; the cohort gathered for training is k_max-small and
# replicates too).
RULES_POPULATION = {
    "clients": ("client",),
    "lanes": ("lane",),
}

# Model-sharding variant for the same scale mesh: when a detector exceeds
# the replicated-size budget (core/scale.py::model_needs_sharding), its
# wide parameter axes ("mlp"/"heads" — the SSD fused projection, attention
# QKV) tensor-parallel over the ``client`` axis while the residual-stream
# dims replicate.  The per-client population arrays keep their RULES_
# POPULATION placement; ``ModelSpec.param_axes`` +
# ``shardctx.sharding_ctx(RULES_MODEL_SCALE, mesh)`` is the whole hook —
# the driver installs the context, the spec declares the axes, and
# ``sanitize_pspec`` drops any partition the dims don't divide.
RULES_MODEL_SCALE = {
    **RULES_POPULATION,
    "embed": None,
    "mlp": ("client",),
    "heads": ("client",),
    "kv": None,
    "vocab": None,
    "experts": None,
    "layers": None,
    "act_batch": None,
    "act_seq": None,
    "ssm_state": None,
}


def population_shardings(mesh: Mesh, pop):
    """Shardings for a :class:`repro.data.synthetic.Population` on a
    ``(lane, client)`` scale mesh: per-client arrays (membership table,
    sizes, quality) shard over ``client``; the shared pool, the test set
    and the shift key replicate.  Row-sharding ``member_idx`` is what
    makes a 10^6-client membership table fit — each device holds
    N/client_shards rows — while the cohort gather stays a plain [k_max]
    gather (GSPMD inserts the collective)."""
    per_client = NamedSharding(mesh, P("client"))
    replicated = NamedSharding(mesh, P())
    return type(pop)(
        pool_x=replicated, pool_y=replicated,
        member_idx=per_client, member_size=per_client,
        data_size=per_client, data_quality=per_client,
        shift_key=replicated,
        test_x=replicated, test_y=replicated,
        feature_shift=pop.feature_shift, feature_shape=pop.feature_shape,
    )


def lane_shardings(mesh: Mesh):
    """(lane-sharded, replicated) NamedShardings for per-lane inputs (seed
    keys, FLParams lanes) on the scale mesh — the 2-D analogue of the
    sweep engine's 1-D lane sharding."""
    return (NamedSharding(mesh, P("lane")), NamedSharding(mesh, P()))


# ---------------------------------------------------------------------------
# Conversions
# ---------------------------------------------------------------------------


def logical_to_pspec(axes: Tuple[Optional[str], ...], rules: dict) -> P:
    parts = []
    used: set = set()
    for a in axes:
        m = rules.get(a) if a else None
        if m is None:
            parts.append(None)
            continue
        m = (m,) if isinstance(m, str) else tuple(m)
        m = tuple(x for x in m if x not in used)
        used.update(m)
        parts.append(m if len(m) != 1 else m[0])
        if not m:
            parts[-1] = None
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def tree_pspecs(axes_tree, rules: dict):
    return jax.tree.map(
        lambda a: logical_to_pspec(a, rules),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(y is None or isinstance(y, str) for y in x),
    )


def tree_shardings(axes_tree, rules: dict, mesh: Mesh):
    return jax.tree.map(
        lambda p: NamedSharding(mesh, p),
        tree_pspecs(axes_tree, rules),
        is_leaf=lambda x: isinstance(x, P),
    )


def divisibility_ok(shape: Tuple[int, ...], spec: P, mesh: Mesh) -> bool:
    """Check a shape divides evenly under a spec for this mesh."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for dim, part in zip(shape, tuple(spec)):
        if part is None:
            continue
        parts = (part,) if isinstance(part, str) else part
        n = int(np.prod([sizes[p] for p in parts]))
        if dim % n:
            return False
    return True


def sanitize_pspec(shape: Tuple[int, ...], spec: P, mesh: Mesh) -> P:
    """Drop partitions that do not divide the dimension evenly (e.g. kv=8
    over model=16) so GSPMD never sees an invalid sharding."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    spec_t = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
    for dim, part in zip(shape, spec_t):
        if part is None:
            out.append(None)
            continue
        parts = (part,) if isinstance(part, str) else tuple(part)
        n = int(np.prod([sizes[p] for p in parts]))
        out.append(part if dim % n == 0 else None)
    while out and out[-1] is None:
        out.pop()
    return P(*out)
