"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) block.

Chunked SSD: the sequence is split into chunks of length Q; within a chunk
the output is the quadratic "attention-like" masked form, across chunks a
linear recurrence carries the [heads, head_dim, state] SSM state.  This is
the TPU-friendly formulation — all heavy ops are MXU einsums; the chunk
recurrence is a ``lax.scan`` over (seq/Q) steps.

Decode: O(1) per token via the recurrent form
    S_t = exp(dt*A) * S_{t-1} + dt * B_t ⊗ x_t ;  y_t = C_t · S_t + D * x_t
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import fan_in_init, rmsnorm
from repro.models.sharding import pm


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def ssd_dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    heads = cfg.ssm_heads or max(1, d_in // cfg.ssm_head_dim)
    return d_in, heads, cfg.ssm_head_dim, cfg.ssm_state


def init_ssd(key, cfg):
    d = cfg.d_model
    d_in, h, p, n = ssd_dims(cfg)
    dt = _dtype(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    # fused input projection: [z (gate), x, B, C, dt]
    d_proj = 2 * d_in + 2 * n + h
    params = {
        "in_proj": pm(fan_in_init(k1, (d, d_proj), dt), "embed", "mlp"),
        "conv_w": pm(fan_in_init(k2, (cfg.conv_width, d_in + 2 * n), dt), None, "mlp"),
        "conv_b": pm(jnp.zeros((d_in + 2 * n,), dt), "mlp"),
        "A_log": pm(jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)), None),
        "D": pm(jnp.ones((h,), jnp.float32), None),
        "dt_bias": pm(jnp.zeros((h,), jnp.float32), None),
        "norm_scale": pm(jnp.ones((d_in,), dt), "mlp"),
        "out_proj": pm(fan_in_init(k4, (d_in, d), dt), "mlp", "embed"),
    }
    return params


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x: [b, l, c]; w: [k, c].

    With ``state`` ([b, k-1, c]) performs a streaming step and returns the new
    state as well.
    """
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)
        new_state = xp[:, -(k - 1):, :] if k > 1 else None
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
        new_state = xp[:, -(k - 1):, :] if k > 1 else None
    out = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(k)) + b
    return jax.nn.silu(out), new_state


def _segsum(a):
    """Stable segment-sum: out[..., i, j] = sum_{j < m <= i} a[..., m].

    a: [..., q]; returns [..., q, q] with -inf above the diagonal.
    """
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(q)[:, None]
    j = jnp.arange(q)[None, :]
    return jnp.where(j <= i, diff, -jnp.inf)


def _project(params, x, cfg):
    """Fused input projection -> (z gate [b,l,d_in], xBC [b,l,d_in+2n], dt [b,l,h])."""
    d_in, h, p, n = ssd_dims(cfg)
    proj = jnp.einsum("bld,de->ble", x, params["in_proj"])
    z = proj[..., :d_in]
    xbc = proj[..., d_in : 2 * d_in + 2 * n]
    dtp = proj[..., 2 * d_in + 2 * n :]
    dt = jax.nn.softplus(dtp.astype(jnp.float32) + params["dt_bias"])  # [b,l,h]
    return z, xbc, dt


def chunk_scan_via(linear_scan):
    """Adapt an ``(a, x, h0) -> (hs, h_last)`` diagonal linear-recurrence
    primitive (``kernels.ops.rglru_scan`` or ``kernels.ref.rglru_scan_ref``)
    into the inter-chunk state scan of :func:`ssd_chunked`.

    The chunk recurrence ``s_new = s * dec + st`` is elementwise over the
    flattened [h*p*n] state with the per-chunk decay broadcast over (p, n) —
    exactly the RG-LRU scan's ``h = a·h + x`` form, so the Pallas kernel
    serves both sequence families.  Returns a ``scan_fn`` with the
    ``(chunk_decay [b,nc,h], states [b,nc,h,p,n], s0 [b,h,p,n]) ->
    (final_state, prev_states)`` contract ``ssd_chunked`` expects.
    """

    def scan_fn(chunk_decay, states, s0):
        b, nc, h, p, n = states.shape
        w = h * p * n
        a = jnp.broadcast_to(
            chunk_decay[:, :, :, None, None], states.shape
        ).reshape(b, nc, w)
        hs, h_last = linear_scan(a, states.reshape(b, nc, w), s0.reshape(b, w))
        # scan contract returns the state BEFORE each chunk's update
        prev = jnp.concatenate([s0.reshape(b, 1, w), hs[:, :-1]], axis=1)
        return h_last.reshape(b, h, p, n), prev.reshape(b, nc, h, p, n)

    return scan_fn


def ssd_chunked(x, dt, A, B, C, chunk: int, init_state=None, scan_fn=None):
    """Chunked SSD scan.

    x: [b, l, h, p]; dt: [b, l, h]; A: [h] (positive, used as -A);
    B, C: [b, l, n].  Returns (y [b,l,h,p], final_state [b,h,p,n]).

    ``scan_fn`` (default None = the inline ``lax.scan``) swaps the
    inter-chunk state recurrence for a routed implementation (see
    :func:`chunk_scan_via`); the quadratic intra-chunk math is shared.
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    q = min(chunk, l)
    assert l % q == 0, (l, q)
    nc = l // q

    dA = (-A) * dt  # [b,l,h]
    xr = x.reshape(b, nc, q, h, p)
    dtr = dt.reshape(b, nc, q, h)
    dAr = dA.reshape(b, nc, q, h)
    Br = B.reshape(b, nc, q, n)
    Cr = C.reshape(b, nc, q, n)

    # intra-chunk (quadratic) term
    L = jnp.exp(_segsum(dAr.transpose(0, 1, 3, 2)))  # [b,nc,h,q,q]
    CB = jnp.einsum("bcin,bcjn->bcij", Cr, Br)  # [b,nc,q,q]
    M = CB[:, :, None] * L  # [b,nc,h,q,q]
    y_diag = jnp.einsum("bchij,bcjh,bcjhp->bcihp", M, dtr, xr)

    # per-chunk final states
    dA_cum = jnp.cumsum(dAr, axis=2)  # [b,nc,q,h]
    decay_states = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)  # [b,nc,q,h]
    states = jnp.einsum("bcjn,bcjh,bcjh,bcjhp->bchpn", Br, decay_states, dtr, xr)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(jnp.sum(dAr, axis=2))  # [b,nc,h]
    s0 = (
        jnp.zeros((b, h, p, n), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )

    if scan_fn is None:

        def step(s, inp):
            dec, st = inp
            s_new = s * dec[:, :, None, None] + st
            return s_new, s

        (final_state, prev_states) = jax.lax.scan(
            step,
            s0,
            (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)),
        )
        prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # [b,nc,h,p,n]
    else:
        final_state, prev_states = scan_fn(chunk_decay, states, s0)

    # inter-chunk contribution
    state_decay = jnp.exp(dA_cum)  # decay from chunk start to position i
    y_off = jnp.einsum("bcin,bchpn,bcih->bcihp", Cr, prev_states, state_decay)

    y = (y_diag + y_off).reshape(b, l, h, p)
    return y, final_state


def ssd_block(params, x, cfg, state=None, scan_fn=None):
    """Full Mamba-2 mixer.  x: [b, l, d] -> ([b, l, d], cache).

    cache = {"ssm": [b,h,p,n] f32, "conv": [b, k-1, d_in+2n]}

    ``scan_fn`` threads through to :func:`ssd_chunked` (routed inter-chunk
    recurrence; None keeps the inline ``lax.scan``).
    """
    d_in, h, p, n = ssd_dims(cfg)
    z, xbc, dt = _project(params, x, cfg)
    conv_state = state["conv"] if state is not None else None
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"], conv_state)
    xs = xbc[..., :d_in].reshape(x.shape[0], x.shape[1], h, p).astype(jnp.float32)
    B = xbc[..., d_in : d_in + n].astype(jnp.float32)
    C = xbc[..., d_in + n :].astype(jnp.float32)
    A = jnp.exp(params["A_log"])  # [h] positive
    init_state = state["ssm"] if state is not None else None
    y, final = ssd_chunked(xs, dt, A, B, C, cfg.ssm_chunk, init_state,
                           scan_fn=scan_fn)
    y = y + params["D"][None, None, :, None] * xs
    y = y.reshape(x.shape[0], x.shape[1], d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm({"scale": params["norm_scale"]}, y, cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, params["out_proj"])
    cache = {"ssm": final, "conv": new_conv}
    return out, cache


def ssd_decode_step(params, x, cache, cfg):
    """One-token recurrent step.  x: [b, 1, d]."""
    d_in, h, p, n = ssd_dims(cfg)
    z, xbc, dt = _project(params, x, cfg)
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"], cache["conv"])
    xs = xbc[..., :d_in].reshape(x.shape[0], 1, h, p).astype(jnp.float32)[:, 0]
    B = xbc[..., d_in : d_in + n].astype(jnp.float32)[:, 0]  # [b,n]
    C = xbc[..., d_in + n :].astype(jnp.float32)[:, 0]
    A = jnp.exp(params["A_log"])
    dt0 = dt[:, 0]  # [b,h]
    dA = jnp.exp(-A * dt0)  # [b,h]
    s = cache["ssm"] * dA[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt0, xs, B
    )
    y = jnp.einsum("bn,bhpn->bhp", C, s) + params["D"][None, :, None] * xs
    y = y.reshape(x.shape[0], 1, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm({"scale": params["norm_scale"]}, y, cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, params["out_proj"])
    return out, {"ssm": s, "conv": new_conv}


def init_ssd_cache(cfg, batch):
    d_in, h, p, n = ssd_dims(cfg)
    return {
        "ssm": jnp.zeros((batch, h, p, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, d_in + 2 * n), jnp.bfloat16),
    }
