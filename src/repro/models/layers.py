"""Core neural-net layers (pure functions + explicit param pytrees).

Every ``init_*`` returns a pytree of :class:`ParamMeta` (value + logical
sharding axes); ``repro.models.sharding.split_meta`` separates values from
axis metadata.  Apply functions take the *value* pytree.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.sharding import pm


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def normal_init(key, shape, dtype, stddev):
    return (stddev * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def fan_in_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    return normal_init(key, shape, dtype, 1.0 / math.sqrt(max(fan_in, 1)))


# ---------------------------------------------------------------------------
# Normalisation
# ---------------------------------------------------------------------------


def init_rmsnorm(key, d, cfg):
    del key
    return {"scale": pm(jnp.ones((d,), _dtype(cfg)), "embed")}


def rmsnorm(params, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(key, d, cfg):
    del key
    dt = _dtype(cfg)
    return {"scale": pm(jnp.ones((d,), dt), "embed"), "bias": pm(jnp.zeros((d,), dt), "embed")}


def layernorm(params, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Linear / embedding
# ---------------------------------------------------------------------------


def init_dense(key, d_in, d_out, cfg, axes=("embed", "mlp"), bias=False):
    dt = _dtype(cfg)
    p = {"w": pm(fan_in_init(key, (d_in, d_out), dt), *axes)}
    if bias:
        p["b"] = pm(jnp.zeros((d_out,), dt), axes[1])
    return p


def dense(params, x):
    y = jnp.einsum("...i,io->...o", x, params["w"])
    if "b" in params:
        y = y + params["b"]
    return y


def init_embedding(key, vocab, d, cfg):
    dt = _dtype(cfg)
    return {"table": pm(normal_init(key, (vocab, d), dt, 0.02), "vocab", "embed")}


def embed(params, ids):
    return jnp.take(params["table"], ids, axis=0)


def unembed(params, x):
    """Project hidden states to logits with the (tied or separate) table."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32), params["table"].astype(jnp.float32))


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponents)  # [head_dim//2]


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: [..., seq, heads, head_dim]; positions: [..., seq] (int32)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., seq, hd/2]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions_3d, sections: Tuple[int, int, int], theta: float = 10_000.0):
    """Qwen2-VL multimodal RoPE.

    positions_3d: [..., seq, 3] (temporal, height, width position ids).
    ``sections`` splits the head_dim//2 frequency bands between t/h/w.
    """
    hd = x.shape[-1]
    half = hd // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(hd, theta)  # [half]
    # choose which positional stream drives each frequency band
    sec_ids = jnp.repeat(
        jnp.arange(3), jnp.array(sections), total_repeat_length=half
    )  # [half] in {0,1,2}
    pos = jnp.take_along_axis(
        positions_3d.astype(jnp.float32),
        jnp.broadcast_to(sec_ids, positions_3d.shape[:-1] + (half,)).astype(jnp.int32),
        axis=-1,
    )  # [..., seq, half]
    angles = pos * freqs
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP blocks
# ---------------------------------------------------------------------------


def init_mlp(key, cfg, d_model=None, d_ff=None):
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.act in ("swiglu", "geglu"):
        return {
            "wi": init_dense(k1, d, f, cfg, axes=("embed", "mlp")),
            "wg": init_dense(k2, d, f, cfg, axes=("embed", "mlp")),
            "wo": init_dense(k3, f, d, cfg, axes=("mlp", "embed")),
        }
    return {
        "wi": init_dense(k1, d, f, cfg, axes=("embed", "mlp")),
        "wo": init_dense(k3, f, d, cfg, axes=("mlp", "embed")),
    }


def mlp(params, x, act: str):
    h = dense(params["wi"], x)
    if act == "swiglu":
        h = jax.nn.silu(dense(params["wg"], x)) * h
    elif act == "geglu":
        h = jax.nn.gelu(dense(params["wg"], x)) * h
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        h = jax.nn.relu(h)
    return dense(params["wo"], h)
