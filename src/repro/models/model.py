"""Unified model API over all assigned families.

``build(cfg)`` returns a :class:`Model` exposing
  init / axes / loss / forward / decode_step / init_cache / input_specs
uniformly, so the FL round engine, the launcher and the dry-run never branch
on family.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec as E
from repro.models import transformer as T


def parse_long_variant(cfg: ModelConfig) -> Optional[int]:
    """'swa-4096' -> 4096."""
    if cfg.long_context_variant and cfg.long_context_variant.startswith("swa-"):
        return int(cfg.long_context_variant.split("-")[1])
    return None


def effective_window(cfg: ModelConfig, shape: Optional[ShapeConfig]) -> Optional[int]:
    """Attention window override for a given input shape.

    For ``long_500k`` full-attention archs run their explicitly-labeled
    sliding-window variant (DESIGN.md §5); all other shapes use the published
    attention (cfg.sliding_window, usually None).

    An attention-family config that reaches ``long_500k`` with no window
    anywhere — no ``sliding_window``, no ``swa-*`` long-context variant,
    and a family that does not support long context — is a config error:
    it would silently lower full O(L²) attention over 524288 positions.
    Rejected here, which is config-build time (``input_specs`` /
    ``build_train_step`` both resolve the window before any compile).
    """
    if shape is not None and shape.name == "long_500k" and cfg.family != "ssm":
        if cfg.sliding_window is not None:
            return cfg.sliding_window
        window = parse_long_variant(cfg)
        if window is None and not cfg.supports_long_context():
            raise ValueError(
                f"arch {cfg.name!r} (family {cfg.family!r}) cannot run the "
                "long_500k shape: it has no sliding_window, no 'swa-*' "
                "long_context_variant, and its family does not support "
                "long context — full attention over 524288 positions is "
                "never intended.  Label the config with "
                "long_context_variant='swa-<window>' or pick an "
                "ssm/hybrid arch")
        return window
    return cfg.sliding_window


def mrope_positions(batch: int, n_front: int, n_text: int, grid_w: int = 16):
    """Qwen2-VL style (t, h, w) position ids for [image patches; text]."""
    img_i = jnp.arange(n_front, dtype=jnp.int32)
    img = jnp.stack([jnp.zeros_like(img_i), img_i // grid_w, img_i % grid_w], axis=-1)
    txt_i = jnp.arange(n_text, dtype=jnp.int32) + (n_front // grid_w)
    txt = jnp.stack([txt_i, txt_i, txt_i], axis=-1)
    pos = jnp.concatenate([img, txt], axis=0)
    return jnp.broadcast_to(pos, (batch,) + pos.shape)


class Model:
    """Family-dispatching wrapper (stateless; params are explicit)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.is_encdec = cfg.enc_layers > 0

    # -- parameters ---------------------------------------------------------
    def init(self, key):
        if self.is_encdec:
            return E.init_encdec(key, self.cfg)[0]
        return T.init_lm(key, self.cfg)[0]

    def axes(self):
        if self.is_encdec:
            return E.encdec_axes(self.cfg)
        return T.lm_axes(self.cfg)

    def param_shapes(self):
        if self.is_encdec:
            meta = jax.eval_shape(
                lambda k: E.init_encdec_meta(k, self.cfg), jax.random.key(0)
            )
            from repro.models.sharding import split_meta

            return split_meta(meta)[0]
        return T.lm_param_shapes(self.cfg)

    # -- training -----------------------------------------------------------
    def loss(self, params, batch: Dict[str, Any], *, remat="full", impl="ref",
             remat_group=1):
        cfg = self.cfg
        if self.is_encdec:
            return E.encdec_loss(
                params, cfg, batch["frontend"], batch["tokens"], batch["labels"], remat=remat
            )
        extra = batch.get("frontend")
        if cfg.mrope_sections is not None and extra is not None:
            # VLM: build 3-D positions for [patches; text]
            b, n_text = batch["tokens"].shape
            pos = mrope_positions(b, extra.shape[1], n_text)
            logits, aux = T.lm_forward(
                params, cfg, batch["tokens"], pos, extra_embeds=extra,
                mode="train", remat=remat, impl=impl,
            )
            logits = logits[:, extra.shape[1]:]
            labels = batch["labels"]
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(
                logits, jnp.maximum(labels, 0)[..., None], axis=-1
            )[..., 0]
            mask = (labels >= 0).astype(jnp.float32)
            return jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0) + aux
        return T.lm_loss(
            params, cfg, batch["tokens"], batch["labels"],
            remat=remat, impl=impl, extra_embeds=extra, remat_group=remat_group,
        )

    # -- inference ----------------------------------------------------------
    def forward(self, params, batch, *, remat="none", impl="ref", window=None,
                last_only=False):
        cfg = self.cfg
        if self.is_encdec:
            return E.encdec_forward(
                params, cfg, batch["frontend"], batch["tokens"], remat=remat,
                window=window, last_only=last_only,
            )[0]
        extra = batch.get("frontend")
        pos = None
        if cfg.mrope_sections is not None and extra is not None:
            pos = mrope_positions(batch["tokens"].shape[0], extra.shape[1],
                                  batch["tokens"].shape[1])
        logits, _ = T.lm_forward(
            params, cfg, batch["tokens"], pos, extra_embeds=extra,
            mode="prefill", remat=remat, impl=impl, window_override=window,
            last_only=last_only,
        )
        return logits

    def decode_step(self, params, token, caches, index, *, window=None):
        cfg = self.cfg
        if self.is_encdec:
            return E.encdec_decode_step(params, cfg, token, caches, index, window=window)
        return T.lm_decode_step(params, cfg, token, caches, index, window_override=window)

    def init_cache(self, batch: int, cache_len: int, *, window=None, params=None,
                   enc_out=None):
        cfg = self.cfg
        if self.is_encdec:
            if enc_out is None:
                enc_out = jnp.zeros((batch, cfg.enc_seq, cfg.d_model), jnp.dtype(cfg.dtype))
            return E.init_decode_cache(params, cfg, batch, cache_len, enc_out, window=window)
        return T.stack_cache(cfg, batch, cache_len, window_override=window)

    def cache_specs(self, batch: int, cache_len: int, *, window=None):
        """ShapeDtypeStruct tree of the decode cache (no allocation)."""
        if self.is_encdec:
            pshapes = self.param_shapes()
            return jax.eval_shape(
                lambda p: self.init_cache(batch, cache_len, window=window, params=p),
                pshapes,
            )
        return jax.eval_shape(lambda: self.init_cache(batch, cache_len, window=window))

    # -- dry-run input specs --------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of a step."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        sd = jax.ShapeDtypeStruct
        dt = jnp.dtype(cfg.dtype)
        window = effective_window(cfg, shape)

        if shape.mode in ("train", "prefill"):
            if self.is_encdec:
                return {
                    "frontend": sd((b, cfg.enc_seq, cfg.d_model), dt),
                    "tokens": sd((b, s), i32),
                    "labels": sd((b, s), i32),
                }
            specs = {}
            n_text = s
            if cfg.frontend != "none" and cfg.frontend_tokens:
                n_text = s - cfg.frontend_tokens
                specs["frontend"] = sd((b, cfg.frontend_tokens, cfg.d_model), dt)
            specs["tokens"] = sd((b, n_text), i32)
            specs["labels"] = sd((b, n_text), i32)
            if shape.mode == "prefill":
                specs.pop("labels")
            return specs

        # decode: one new token against a cache of seq_len context
        caches = jax.tree.map(
            lambda x: sd(x.shape, x.dtype),
            self.cache_specs(b, s, window=window),
            is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict),
        )
        return {
            "token": sd((b, 1), i32),
            "caches": caches,
            "index": sd((), i32),
        }


def build(cfg: ModelConfig) -> Model:
    return Model(cfg)
