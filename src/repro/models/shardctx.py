"""Activation-sharding context.

Step builders (launch/, core/rounds.py) install a rule table + mesh; model
code calls :func:`constrain` with *logical* axis names.  Outside any context
(CPU unit tests) ``constrain`` is the identity, so the model zoo stays
mesh-agnostic.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.sharding import logical_to_pspec, sanitize_pspec

_STATE = {"rules": None, "mesh": None}


@contextlib.contextmanager
def sharding_ctx(rules: dict, mesh: Optional[Mesh] = None):
    prev = dict(_STATE)
    _STATE["rules"] = rules
    _STATE["mesh"] = mesh
    try:
        yield
    finally:
        _STATE.update(prev)


def active() -> bool:
    return _STATE["rules"] is not None


def constrain(x, *logical_axes):
    """Apply a with_sharding_constraint described by logical axis names."""
    rules = _STATE["rules"]
    if rules is None:
        return x
    spec = logical_to_pspec(tuple(logical_axes), rules)
    mesh = _STATE["mesh"]
    if mesh is not None:
        spec = sanitize_pspec(x.shape, spec, mesh)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return jax.lax.with_sharding_constraint(x, spec)
