"""Pluggable detector architectures for the FL experiment engine (ISSUE 4).

The compiled engine (``train/fl_driver.py``) used to hardcode the paper's
flattened MLP in every model-touching site (init, loss, predict, metrics,
personalisation).  A :class:`ModelSpec` packages exactly the surface the
engine needs — ``init``/``loss``/``logits`` plus the derived
``predict_proba``/``accuracy`` metrics — so any detector family can ride
the sweep/privacy machinery unchanged (DP clip+noise and aggregation are
already pytree-generic; ``core/rounds.py`` was always generic over
``loss_fn``).

Model choice is the STATIC ``FLConfig.model`` field: it survives
``fl_static`` canonicalisation, so the runner cache keys on it and each
architecture compiles exactly once per (statics, shapes) cell — a
model × seed grid is one program per model, not per lane
(``benchmarks/bench_models.py`` asserts this).

Registry contract (``register_model``): a *builder* ``(meta: DataMeta) ->
ModelSpec``.  Binding the dataset metadata at build time keeps the spec's
apply functions in the engine-facing ``(params, x)`` shape — window-native
detectors close over ``meta.feature_shape`` to unflatten the engine's flat
feature vectors back into ``[window, signals]`` CAN windows, while the
data path (padding, device stacking, in-scan batch sampling, lane
sharding) stays byte-identical for every model.

Builtin registry: ``mlp`` (the paper's detector, default — bitwise
identical to the pre-spec engine, pinned by tests/test_models.py) plus the
window-native ROAD detectors in ``models/detectors.py`` (``cnn``,
``rglru``), registered on import.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, Mapping, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import mlp as mlp_lib


class DataMeta(NamedTuple):
    """Dataset-shape metadata a model builder needs (hashable — it is part
    of the compiled-runner cache key in ``train/fl_driver.py``).

    ``feature_shape`` is the *structured* shape of one example whose
    product equals ``n_features``: ``(n_features,)`` for tabular features,
    ``(window, n_signals)`` for raw CAN windows
    (``data/synthetic.make_federated(dataset="road_raw")``).  The engine
    always moves flat ``[batch, n_features]`` arrays; window-native specs
    reshape internally.
    """

    n_features: int
    n_classes: int
    hidden: int                       # generic width knob, per-spec meaning
    feature_shape: Tuple[int, ...]

    @property
    def windowed(self) -> bool:
        return len(self.feature_shape) > 1


def meta_for(fed, hidden: int = 64) -> DataMeta:
    """DataMeta of a :class:`repro.data.synthetic.FederatedData`."""
    shape = getattr(fed, "feature_shape", None) or (fed.n_features,)
    return DataMeta(n_features=fed.n_features, n_classes=fed.n_classes,
                    hidden=hidden, feature_shape=tuple(int(s) for s in shape))


@dataclass(frozen=True)
class ModelSpec:
    """The engine-facing surface of one detector architecture.

    * ``init(key) -> params`` — fresh parameter pytree (the builder bound
      the :class:`DataMeta`).
    * ``loss(params, batch)`` — mean loss of ``{"x": [b, d], "y": [b]}``;
      this is what ``core/rounds.py`` differentiates per client.
    * ``logits(params, x) -> [b, n_classes]`` — the primitive the metrics
      derive from.  Deriving ``accuracy`` from argmax-of-logits (not
      argmax-of-softmax) keeps the ``mlp`` spec bitwise identical to the
      pre-spec engine.
    * ``route_variants`` — optional per-route logits functions for specs
      whose score path has a Pallas-kernel route next to the pure-jnp
      ``kernels/ref`` one (the sequence detectors in
      ``models/detectors.py``: ``attn`` → flash_attention/flash_decode,
      ``ssm``/``rglru`` → ``rglru_scan``).  ``logits`` stays the build-time
      default route, so every existing call site is untouched; the serving
      engine (``repro/serve``) and tests select a route explicitly via
      :meth:`logits_routed` / :meth:`predict_proba_routed`.
    * ``param_axes`` — the sharding hook: an optional thunk returning a
      pytree of logical-axis tuples, one tuple per ``init`` leaf (prefix
      structure is fine — ``jax.tree`` semantics).  The training driver
      calls :meth:`constrain_params` on freshly-initialised params; under
      an active ``models/shardctx`` context the logical axes resolve
      through the installed rules/mesh into GSPMD sharding constraints,
      outside any context it is a no-op — so the spec declares WHERE its
      parameters may shard and the driver decides WHEN (model exceeds the
      replicated-size budget, ``core/scale.py``), with zero effect on
      unsharded programs.
    """

    name: str
    init: Callable
    loss: Callable
    logits: Callable
    route_variants: Optional[Mapping[str, Callable]] = None
    param_axes: Optional[Callable[[], object]] = None

    def logits_routed(self, route: Optional[str] = None) -> Callable:
        """Logits function on an explicit kernel route.  ``None`` resolves
        by backend (``kernels.ops.default_route``: Pallas kernels on TPU,
        ``kernels/ref`` elsewhere); specs without route variants ignore the
        route — their single implementation IS both routes."""
        if self.route_variants is None:
            return self.logits
        from repro.kernels.ops import default_route
        route = route or default_route()
        try:
            return self.route_variants[route]
        except KeyError:
            raise KeyError(
                f"model {self.name!r} has no score route {route!r}; "
                f"available: {tuple(self.route_variants)}") from None

    def predict_proba(self, params, x):
        return jax.nn.softmax(self.logits(params, x), axis=-1)

    def predict_proba_routed(self, params, x, route: Optional[str] = None):
        return jax.nn.softmax(self.logits_routed(route)(params, x), axis=-1)

    def accuracy(self, params, x, y) -> jnp.ndarray:
        pred = jnp.argmax(self.logits(params, x), axis=-1)
        return jnp.mean((pred == y).astype(jnp.float32))

    def param_bytes(self) -> int:
        """Replicated parameter footprint (bytes), via ``jax.eval_shape`` —
        no arrays are materialised.  ``core/scale.py`` compares this against
        the replicated-size budget to decide model sharding."""
        shapes = jax.eval_shape(self.init, jax.random.key(0))
        return sum(
            int(math.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
            for l in jax.tree.leaves(shapes)
        )

    def constrain_params(self, params):
        """Apply the spec's ``param_axes`` as sharding constraints through
        the active ``models/shardctx`` context.  Identity when the spec
        declares no axes or no context is installed (the lowered program is
        unchanged — bitwise-neutral for every unsharded path)."""
        if self.param_axes is None:
            return params
        from repro.models import shardctx
        if not shardctx.active():
            return params
        axes = self.param_axes()
        treedef = jax.tree.structure(params)
        axes_flat = treedef.flatten_up_to(axes)
        out = [shardctx.constrain(p, *a)
               for p, a in zip(jax.tree.leaves(params), axes_flat)]
        return jax.tree.unflatten(treedef, out)


def cross_entropy(logits, y):
    """Mean CE from logits — shared by every non-MLP spec (same math as
    ``mlp_lib.mlp_loss``)."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - gold)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[DataMeta], ModelSpec]] = {}


def register_model(name: str, builder: Callable[[DataMeta], ModelSpec]):
    """Register ``builder(meta) -> ModelSpec`` under ``name`` (the value a
    config's ``FLConfig.model`` field takes)."""
    _REGISTRY[name] = builder


def model_names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def get_model_spec(name: str, meta: DataMeta) -> ModelSpec:
    """Resolve a registered architecture against a dataset's metadata."""
    try:
        builder = _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown FLConfig.model {name!r}; registered: {model_names()}"
        ) from None
    return builder(meta)


def _build_mlp(meta: DataMeta) -> ModelSpec:
    """The paper's flattened-feature MLP — wired straight to ``models/mlp``
    so the spec path is the pre-refactor math, function for function."""
    return ModelSpec(
        name="mlp",
        init=lambda key: mlp_lib.init_mlp(key, meta.n_features, meta.hidden,
                                          meta.n_classes),
        loss=mlp_lib.mlp_loss,
        logits=mlp_lib.mlp_logits,
    )


register_model("mlp", _build_mlp)

# Window-native ROAD detectors self-register on import.
from repro.models import detectors as _detectors  # noqa: E402,F401
