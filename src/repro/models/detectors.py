"""Window-native ROAD detectors (ISSUE 4).

The paper's second workload is ROAD CAN-bus *windows* — a masquerade
attack replays one signal's dynamics on another ID, so the discriminative
signal is temporal/cross-signal structure, which the flattened-feature MLP
can only see through hand-engineered statistics.  These detectors consume
the raw ``[window, n_signals]`` matrix instead
(``data/synthetic.make_federated(dataset="road_raw")`` emits it, flattened
for the generic data path; the specs unflatten via ``DataMeta
.feature_shape``):

* ``cnn`` — a small 1-D CNN over the window axis (signals are channels):
  two conv stages + mean/max pooling over time.  Translation-invariant in
  time, which matches the attack's arbitrary replay shift.
* ``rglru`` — a small recurrent detector on the existing RG-LRU substrate
  (``models/rglru.py``, the RecurrentGemma/Griffin block — input
  projection, gated linear recurrence, gelu gate, output projection),
  mean+last pooled.  ROUTED (ISSUE 10): the ``"kernel"`` route runs the
  ``rglru_scan`` Pallas chunked scan (``rglru_block(impl="flash")``), the
  ``"ref"`` route the model-level ``associative_scan`` — both compute the
  same recurrence, by different parallel decompositions.
* ``ssm`` — a Mamba-2 detector on the SSD substrate (``models/ssm.py``,
  ISSUE 10): embed the window's signals, one ``ssd_block`` mixer
  (chunked state-space duality over the window axis, small-dt init),
  residual, mean+last+max pooled; the score path averages two circular
  time-rolls of the window (stationary signals — a rolled window is a
  valid second view).  ROUTED through the same contract: the inter-chunk
  recurrence is exactly the RG-LRU scan's ``h = a·h + x`` form
  (``ssm.chunk_scan_via``), so the ``"kernel"`` route rides the
  ``rglru_scan`` Pallas kernel and the ``"ref"`` route the sequential
  ``kernels/ref`` oracle — both sequential f32 scans, bitwise-equal
  (tests/test_kernels.py).
* ``attn`` — a causal self-attention detector (ISSUE 7) whose score path
  is ROUTED: one causal attention block over the window plus a
  learned-query read-out that is exactly a one-token decode against the
  window's KV.  The ``"kernel"`` route runs
  ``kernels/flash_attention.py`` + ``kernels/flash_decode.py`` (compiled
  Pallas on TPU, interpret elsewhere); the ``"ref"`` route runs the
  pure-jnp ``kernels/ref.py`` oracles.  ``ModelSpec.route_variants``
  carries both; the build-time default (``ModelSpec.logits``) follows
  ``kernels.ops.default_route`` — ref on CPU, kernel on TPU — while
  ``loss`` always differentiates the ref math (the forward-only Pallas
  kernels have no VJP).  This is the serving engine's sequence hot path
  (``repro/serve``, ARCHITECTURE.md §Serving).

Both are plain f32 param pytrees (``layers.fan_in_init``), so DP
clip+noise, aggregation and the scan carry treat them exactly like the
MLP.  ``benchmarks/bench_models.py`` records the AUC comparison —
window-native detectors beat the flattened MLP on raw ROAD windows.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.models.layers import fan_in_init
from repro.models import rglru as rglru_lib
from repro.models import spec as spec_lib
from repro.models import ssm as ssm_lib
from repro.models.sharding import split_meta

_CONV_DN = ("NWC", "WIO", "NWC")  # [b, window, ch] / [k, in, out]


def _require_windowed(meta: spec_lib.DataMeta, name: str):
    if not meta.windowed:
        raise ValueError(
            f"model {name!r} is window-native: it needs a structured "
            f"feature_shape like (window, n_signals) — got "
            f"{meta.feature_shape}; build the federation with "
            "dataset='road_raw' (data/synthetic.make_federated)")


def _unflatten(x, meta: spec_lib.DataMeta):
    return x.reshape(x.shape[:-1] + meta.feature_shape)


# ---------------------------------------------------------------------------
# 1-D CNN over CAN windows
# ---------------------------------------------------------------------------


def _build_cnn(meta: spec_lib.DataMeta) -> spec_lib.ModelSpec:
    _require_windowed(meta, "cnn")
    _, n_signals = meta.feature_shape[0], meta.feature_shape[-1]
    c1 = max(8, meta.hidden // 4)
    c2 = max(16, meta.hidden // 2)
    kw = 5

    def init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "c1": {"w": fan_in_init(k1, (kw, n_signals, c1), jnp.float32,
                                    fan_in=kw * n_signals),
                   "b": jnp.zeros((c1,), jnp.float32)},
            "c2": {"w": fan_in_init(k2, (kw, c1, c2), jnp.float32,
                                    fan_in=kw * c1),
                   "b": jnp.zeros((c2,), jnp.float32)},
            "head": {"w": fan_in_init(k3, (2 * c2, meta.n_classes),
                                      jnp.float32),
                     "b": jnp.zeros((meta.n_classes,), jnp.float32)},
        }

    def logits(params, x):
        h = _unflatten(x, meta)                       # [b, window, signals]
        h = jax.lax.conv_general_dilated(
            h, params["c1"]["w"], window_strides=(1,), padding="SAME",
            dimension_numbers=_CONV_DN) + params["c1"]["b"]
        h = jax.nn.relu(h)
        h = jax.lax.conv_general_dilated(
            h, params["c2"]["w"], window_strides=(2,), padding="SAME",
            dimension_numbers=_CONV_DN) + params["c2"]["b"]
        h = jax.nn.relu(h)
        pooled = jnp.concatenate([h.mean(axis=1), h.max(axis=1)], axis=-1)
        return pooled @ params["head"]["w"] + params["head"]["b"]

    def loss(params, batch):
        return spec_lib.cross_entropy(logits(params, batch["x"]), batch["y"])

    return spec_lib.ModelSpec(name="cnn", init=init, loss=loss, logits=logits)


# ---------------------------------------------------------------------------
# RG-LRU recurrent detector
# ---------------------------------------------------------------------------


class _RecCfg(NamedTuple):
    """Duck-typed stand-in for the ModelConfig fields ``models/rglru.py``
    reads (d_model / lru_width / conv_width / dtype)."""

    d_model: int
    lru_width: int
    conv_width: int
    dtype: str


def _build_rglru(meta: spec_lib.DataMeta) -> spec_lib.ModelSpec:
    _require_windowed(meta, "rglru")
    n_signals = meta.feature_shape[-1]
    d = max(8, meta.hidden // 4)
    cfg = _RecCfg(d_model=d, lru_width=d, conv_width=4, dtype="float32")

    def init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "embed": {"w": fan_in_init(k1, (n_signals, d), jnp.float32),
                      "b": jnp.zeros((d,), jnp.float32)},
            "rec": split_meta(rglru_lib.init_rglru(k2, cfg))[0],
            "head": {"w": fan_in_init(k3, (2 * d, meta.n_classes),
                                      jnp.float32),
                     "b": jnp.zeros((meta.n_classes,), jnp.float32)},
        }

    def make_logits(impl: str):
        def logits(params, x):
            h = _unflatten(x, meta)                   # [b, window, signals]
            h = h @ params["embed"]["w"] + params["embed"]["b"]  # [b, l, d]
            rec, _ = rglru_lib.rglru_block(params["rec"], h, cfg, impl=impl)
            h = h + rec                                # residual
            pooled = jnp.concatenate([h.mean(axis=1), h[:, -1]], axis=-1)
            return pooled @ params["head"]["w"] + params["head"]["b"]

        return logits

    # "ref" is the model-level associative_scan (the pre-ISSUE-10 math, so
    # the build-time default on CPU is byte-identical to PR 4); "kernel"
    # rides the rglru_scan Pallas chunked scan.
    variants = {"kernel": make_logits("flash"), "ref": make_logits("ref")}
    ref_logits = variants["ref"]

    def loss(params, batch):
        return spec_lib.cross_entropy(ref_logits(params, batch["x"]),
                                      batch["y"])

    return spec_lib.ModelSpec(name="rglru", init=init, loss=loss,
                              logits=variants[kops.default_route()],
                              route_variants=variants)


# ---------------------------------------------------------------------------
# Mamba-2 (SSD) detector on the chunked state-space substrate
# ---------------------------------------------------------------------------


class _SsmCfg(NamedTuple):
    """Duck-typed stand-in for the ModelConfig fields ``models/ssm.py``
    reads (ssd_dims / ssd_block)."""

    d_model: int
    ssm_expand: int
    ssm_heads: int
    ssm_head_dim: int
    ssm_state: int
    ssm_chunk: int
    conv_width: int
    norm_eps: float
    dtype: str


def _ssd_scan_fn(route: str):
    """Routed inter-chunk state recurrence for :func:`ssm.ssd_chunked`.

    Both routes run the SAME sequential f32 scan ``s = dec·s + st`` over
    the flattened chunk states; ``kernel`` through the ``rglru_scan``
    Pallas kernel (backend-resolved interpret mode), ``ref`` through the
    ``kernels/ref`` jnp oracle — bitwise-equal, asserted in
    tests/test_kernels.py.  ``loss`` always uses the ref route (the Pallas
    forward has no VJP).
    """
    if route == "kernel":
        return ssm_lib.chunk_scan_via(kops.rglru_scan)
    if route == "ref":
        return ssm_lib.chunk_scan_via(kref.rglru_scan_ref)
    raise KeyError(route)


def _build_ssm(meta: spec_lib.DataMeta) -> spec_lib.ModelSpec:
    _require_windowed(meta, "ssm")
    window, n_signals = meta.feature_shape[0], meta.feature_shape[-1]
    d = max(16, meta.hidden // 4)
    # chunked SSD needs the window to split into equal chunks; several
    # chunks (not one) so the inter-chunk recurrence — the routed kernel —
    # actually carries state.  The kernel's lane width h·p·n = 512 divides
    # its bw tile exactly.
    chunk = next(c for c in (16, 8, 4, 2, 1) if window % c == 0)
    cfg = _SsmCfg(d_model=d, ssm_expand=2, ssm_heads=2,
                  ssm_head_dim=d, ssm_state=16, ssm_chunk=chunk,
                  conv_width=4, norm_eps=1e-6, dtype="float32")
    # score path averages logits over circular time-rolls of the window —
    # the signals are stationary (AR(1) + sinusoid driver) and the
    # masquerade replaces a whole signal, so a rolled window is a valid
    # second view of the same class; averaging the two views is worth
    # ~+0.01 AUC at the bench protocol.  Training stays single-view.
    tta_rolls = (0, window // 2) if window >= 2 else (0,)

    def init(key):
        k1, k3, k2 = jax.random.split(key, 3)
        mix = dict(split_meta(ssm_lib.init_ssd(k2, cfg))[0])
        # slow dynamics at init: the substrate's dt_bias=0 gives
        # dt = softplus(0) ≈ 0.69, i.e. a per-step decay exp(-A·0.69)
        # with half-life under one step even for the slowest head — no
        # temporal memory over a 64-step window.  dt_bias = -2
        # (dt ≈ 0.12) starts the heads with usable 8–60-step memory
        # (the standard Mamba small-dt init).
        mix["dt_bias"] = jnp.full_like(mix["dt_bias"], -2.0)
        return {
            "embed": {"w": fan_in_init(k1, (n_signals, d), jnp.float32),
                      "b": jnp.zeros((d,), jnp.float32)},
            "mix": mix,
            "head": {"w": fan_in_init(k3, (3 * d, meta.n_classes),
                                      jnp.float32),
                     "b": jnp.zeros((meta.n_classes,), jnp.float32)},
        }

    def make_one_view(route: str):
        scan_fn = _ssd_scan_fn(route)

        def one_view(params, hw):
            h = hw @ params["embed"]["w"] + params["embed"]["b"]  # [b, l, d]
            y, _ = ssm_lib.ssd_block(params["mix"], h, cfg, scan_fn=scan_fn)
            h = h + y                                  # residual
            pooled = jnp.concatenate(
                [h.mean(axis=1), h[:, -1], h.max(axis=1)], axis=-1)
            return pooled @ params["head"]["w"] + params["head"]["b"]

        return one_view

    def make_logits(route: str):
        one_view = make_one_view(route)

        def logits(params, x):
            hw = _unflatten(x, meta)                  # [b, window, signals]
            views = [one_view(params,
                              jnp.roll(hw, r, axis=1) if r else hw)
                     for r in tta_rolls]
            return sum(views) / len(views)

        return logits

    variants = {"kernel": make_logits("kernel"), "ref": make_logits("ref")}
    ref_one_view = make_one_view("ref")

    def loss(params, batch):
        # always the differentiable ref math (Pallas forwards have no
        # VJP), single view — the roll averaging is a score-path device
        return spec_lib.cross_entropy(
            ref_one_view(params, _unflatten(batch["x"], meta)),
            batch["y"])

    def param_axes():
        # the SSD substrate's ParamMeta axes, recovered shape-free; the
        # wide "mlp" dims (fused in_proj, conv channels, out_proj rows)
        # are what RULES_MODEL_SCALE tensor-parallels over `client`.
        mix_axes = split_meta(jax.eval_shape(
            lambda: ssm_lib.init_ssd(jax.random.key(0), cfg)))[1]
        return {
            "embed": {"w": (None, "embed"), "b": ("embed",)},
            "mix": mix_axes,
            "head": {"w": (None, None), "b": (None,)},
        }

    return spec_lib.ModelSpec(name="ssm", init=init, loss=loss,
                              logits=variants[kops.default_route()],
                              route_variants=variants,
                              param_axes=param_axes)


# ---------------------------------------------------------------------------
# Routed causal-attention detector (the serving engine's sequence hot path)
# ---------------------------------------------------------------------------

_ATTN_HEADS = 2


def _attn_primitives(route: str):
    """(attention, decode) primitives for one score route.

    ``kernel``: the Pallas kernels with backend-resolved interpret mode
    (``flash_decode.resolve_interpret`` — compiled on TPU, interpret
    elsewhere).  ``ref``: the pure-jnp oracles, which are also the
    differentiable math ``loss`` uses.  Window lengths ≤ 128 always satisfy
    the kernels' block-divisibility (bq/bk clamp to the sequence length).
    """
    if route == "kernel":
        return (lambda q, k, v: kops.flash_attention(q, k, v, causal=True),
                lambda q, k, v, ln: kops.flash_decode(q, k, v, ln))
    if route == "ref":
        return (lambda q, k, v: kref.flash_attention_ref(q, k, v, causal=True),
                kref.flash_decode_ref)
    raise KeyError(route)


def _build_attn(meta: spec_lib.DataMeta) -> spec_lib.ModelSpec:
    _require_windowed(meta, "attn")
    window, n_signals = meta.feature_shape[0], meta.feature_shape[-1]
    h = _ATTN_HEADS
    d = max(16, (meta.hidden // 4 // (2 * h)) * 2 * h)
    dh = d // h

    def init(key):
        ks = jax.random.split(key, 9)
        lin = lambda k, a, b: fan_in_init(k, (a, b), jnp.float32)
        return {
            "embed": {"w": lin(ks[0], n_signals, d),
                      "b": jnp.zeros((d,), jnp.float32)},
            "pos": 0.02 * jax.random.normal(ks[1], (window, d), jnp.float32),
            "wq": lin(ks[2], d, d), "wk": lin(ks[3], d, d),
            "wv": lin(ks[4], d, d), "wo": lin(ks[5], d, d),
            # read-out: a learned query decoding against the window's KV
            "rq": 0.5 * jax.random.normal(ks[6], (h, dh), jnp.float32),
            "rkv": {"wk": lin(ks[7], d, d), "wv": lin(ks[8], d, d)},
            "head": {"w": fan_in_init(jax.random.fold_in(key, 9),
                                      (2 * d, meta.n_classes), jnp.float32),
                     "b": jnp.zeros((meta.n_classes,), jnp.float32)},
        }

    def make_logits(route: str):
        attention, decode = _attn_primitives(route)

        def logits(params, x):
            hseq = _unflatten(x, meta)                 # [b, T, signals]
            b = hseq.shape[0]
            hseq = hseq @ params["embed"]["w"] + params["embed"]["b"]
            hseq = hseq + params["pos"]                # [b, T, d]
            q = (hseq @ params["wq"]).reshape(b, window, h, dh)
            k = (hseq @ params["wk"]).reshape(b, window, h, dh)
            v = (hseq @ params["wv"]).reshape(b, window, h, dh)
            o = attention(q, k, v).reshape(b, window, d)
            hseq = hseq + o @ params["wo"]             # residual
            k2 = (hseq @ params["rkv"]["wk"]).reshape(b, window, h, dh)
            v2 = (hseq @ params["rkv"]["wv"]).reshape(b, window, h, dh)
            qr = jnp.broadcast_to(params["rq"], (b, h, dh))
            ro = decode(qr, k2, v2,
                        jnp.full((b,), window, jnp.int32)).reshape(b, d)
            pooled = jnp.concatenate([ro, hseq.mean(axis=1)], axis=-1)
            return pooled @ params["head"]["w"] + params["head"]["b"]

        return logits

    variants = {"kernel": make_logits("kernel"), "ref": make_logits("ref")}
    ref_logits = variants["ref"]

    def loss(params, batch):
        # always the differentiable ref math (Pallas forwards have no VJP)
        return spec_lib.cross_entropy(ref_logits(params, batch["x"]),
                                      batch["y"])

    def param_axes():
        qkv = ("embed", "heads")
        return {
            "embed": {"w": (None, "embed"), "b": ("embed",)},
            "pos": (None, "embed"),
            "wq": qkv, "wk": qkv, "wv": qkv, "wo": ("heads", "embed"),
            "rq": ("heads", None),
            "rkv": {"wk": qkv, "wv": qkv},
            "head": {"w": (None, None), "b": (None,)},
        }

    return spec_lib.ModelSpec(name="attn", init=init, loss=loss,
                              logits=variants[kops.default_route()],
                              route_variants=variants,
                              param_axes=param_axes)


spec_lib.register_model("cnn", _build_cnn)
spec_lib.register_model("rglru", _build_rglru)
spec_lib.register_model("ssm", _build_ssm)
spec_lib.register_model("attn", _build_attn)
