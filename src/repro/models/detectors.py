"""Window-native ROAD detectors (ISSUE 4).

The paper's second workload is ROAD CAN-bus *windows* — a masquerade
attack replays one signal's dynamics on another ID, so the discriminative
signal is temporal/cross-signal structure, which the flattened-feature MLP
can only see through hand-engineered statistics.  These detectors consume
the raw ``[window, n_signals]`` matrix instead
(``data/synthetic.make_federated(dataset="road_raw")`` emits it, flattened
for the generic data path; the specs unflatten via ``DataMeta
.feature_shape``):

* ``cnn`` — a small 1-D CNN over the window axis (signals are channels):
  two conv stages + mean/max pooling over time.  Translation-invariant in
  time, which matches the attack's arbitrary replay shift.
* ``rglru`` — a small recurrent detector on the existing RG-LRU substrate
  (``models/rglru.py``, the RecurrentGemma/Griffin block — input
  projection, gated linear recurrence via ``associative_scan``, gelu gate,
  output projection), mean+last pooled.  Exercises the repo's
  recurrent/SSM machinery on the anomaly workload.

Both are plain f32 param pytrees (``layers.fan_in_init``), so DP
clip+noise, aggregation and the scan carry treat them exactly like the
MLP.  ``benchmarks/bench_models.py`` records the AUC comparison —
window-native detectors beat the flattened MLP on raw ROAD windows.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import fan_in_init
from repro.models import rglru as rglru_lib
from repro.models import spec as spec_lib
from repro.models.sharding import split_meta

_CONV_DN = ("NWC", "WIO", "NWC")  # [b, window, ch] / [k, in, out]


def _require_windowed(meta: spec_lib.DataMeta, name: str):
    if not meta.windowed:
        raise ValueError(
            f"model {name!r} is window-native: it needs a structured "
            f"feature_shape like (window, n_signals) — got "
            f"{meta.feature_shape}; build the federation with "
            "dataset='road_raw' (data/synthetic.make_federated)")


def _unflatten(x, meta: spec_lib.DataMeta):
    return x.reshape(x.shape[:-1] + meta.feature_shape)


# ---------------------------------------------------------------------------
# 1-D CNN over CAN windows
# ---------------------------------------------------------------------------


def _build_cnn(meta: spec_lib.DataMeta) -> spec_lib.ModelSpec:
    _require_windowed(meta, "cnn")
    _, n_signals = meta.feature_shape[0], meta.feature_shape[-1]
    c1 = max(8, meta.hidden // 4)
    c2 = max(16, meta.hidden // 2)
    kw = 5

    def init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "c1": {"w": fan_in_init(k1, (kw, n_signals, c1), jnp.float32,
                                    fan_in=kw * n_signals),
                   "b": jnp.zeros((c1,), jnp.float32)},
            "c2": {"w": fan_in_init(k2, (kw, c1, c2), jnp.float32,
                                    fan_in=kw * c1),
                   "b": jnp.zeros((c2,), jnp.float32)},
            "head": {"w": fan_in_init(k3, (2 * c2, meta.n_classes),
                                      jnp.float32),
                     "b": jnp.zeros((meta.n_classes,), jnp.float32)},
        }

    def logits(params, x):
        h = _unflatten(x, meta)                       # [b, window, signals]
        h = jax.lax.conv_general_dilated(
            h, params["c1"]["w"], window_strides=(1,), padding="SAME",
            dimension_numbers=_CONV_DN) + params["c1"]["b"]
        h = jax.nn.relu(h)
        h = jax.lax.conv_general_dilated(
            h, params["c2"]["w"], window_strides=(2,), padding="SAME",
            dimension_numbers=_CONV_DN) + params["c2"]["b"]
        h = jax.nn.relu(h)
        pooled = jnp.concatenate([h.mean(axis=1), h.max(axis=1)], axis=-1)
        return pooled @ params["head"]["w"] + params["head"]["b"]

    def loss(params, batch):
        return spec_lib.cross_entropy(logits(params, batch["x"]), batch["y"])

    return spec_lib.ModelSpec(name="cnn", init=init, loss=loss, logits=logits)


# ---------------------------------------------------------------------------
# RG-LRU recurrent detector
# ---------------------------------------------------------------------------


class _RecCfg(NamedTuple):
    """Duck-typed stand-in for the ModelConfig fields ``models/rglru.py``
    reads (d_model / lru_width / conv_width / dtype)."""

    d_model: int
    lru_width: int
    conv_width: int
    dtype: str


def _build_rglru(meta: spec_lib.DataMeta) -> spec_lib.ModelSpec:
    _require_windowed(meta, "rglru")
    n_signals = meta.feature_shape[-1]
    d = max(8, meta.hidden // 4)
    cfg = _RecCfg(d_model=d, lru_width=d, conv_width=4, dtype="float32")

    def init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "embed": {"w": fan_in_init(k1, (n_signals, d), jnp.float32),
                      "b": jnp.zeros((d,), jnp.float32)},
            "rec": split_meta(rglru_lib.init_rglru(k2, cfg))[0],
            "head": {"w": fan_in_init(k3, (2 * d, meta.n_classes),
                                      jnp.float32),
                     "b": jnp.zeros((meta.n_classes,), jnp.float32)},
        }

    def logits(params, x):
        h = _unflatten(x, meta)                       # [b, window, signals]
        h = h @ params["embed"]["w"] + params["embed"]["b"]  # [b, l, d]
        rec, _ = rglru_lib.rglru_block(params["rec"], h, cfg)
        h = h + rec                                    # residual
        pooled = jnp.concatenate([h.mean(axis=1), h[:, -1]], axis=-1)
        return pooled @ params["head"]["w"] + params["head"]["b"]

    def loss(params, batch):
        return spec_lib.cross_entropy(logits(params, batch["x"]), batch["y"])

    return spec_lib.ModelSpec(name="rglru", init=init, loss=loss,
                              logits=logits)


spec_lib.register_model("cnn", _build_cnn)
spec_lib.register_model("rglru", _build_rglru)
