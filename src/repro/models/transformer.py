"""Decoder-only transformer stack assembling the block zoo.

Layers are stacked per *segment* (see ``ModelConfig.segments``): each segment
is a super-block (e.g. ("rec","rec","attn") for RecurrentGemma) repeated N
times; parameters are stacked along a leading "layers" axis and executed with
``jax.lax.scan`` (+ optional remat) so the lowered HLO stays small even for
88-layer models.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib
from repro.models.shardctx import constrain
from repro.models.sharding import add_axis, pm, split_meta

VOCAB_PAD_TO = 256

# MoE dispatch implementation: "einsum" (GShard one-hot, baseline) or
# "scatter" (index-based, EXPERIMENTS.md §Perf C1).  A single-element list so
# step builders can flip it at trace time without threading a kwarg through
# every block signature.
MOE_IMPL = ["einsum"]


def padded_vocab(cfg) -> int:
    return -(-cfg.vocab_size // VOCAB_PAD_TO) * VOCAB_PAD_TO


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def init_block(key, cfg, kind: str):
    d = cfg.d_model
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if kind == "attn":
        return {
            "ln1": L.init_rmsnorm(k1, d, cfg),
            "attn": attn_lib.init_attention(k2, cfg),
            "ln2": L.init_rmsnorm(k3, d, cfg),
            "mlp": L.init_mlp(k4, cfg),
        }
    if kind == "moe":
        return {
            "ln1": L.init_rmsnorm(k1, d, cfg),
            "attn": attn_lib.init_attention(k2, cfg),
            "ln2": L.init_rmsnorm(k3, d, cfg),
            "moe": moe_lib.init_moe(k4, cfg),
        }
    if kind == "rec":
        return {
            "ln1": L.init_rmsnorm(k1, d, cfg),
            "rec": rglru_lib.init_rglru(k2, cfg),
            "ln2": L.init_rmsnorm(k3, d, cfg),
            "mlp": L.init_mlp(k4, cfg),
        }
    if kind == "ssd":
        return {
            "ln": L.init_rmsnorm(k1, d, cfg),
            "ssd": ssm_lib.init_ssd(k2, cfg),
        }
    raise ValueError(kind)


def _attn_window(cfg, kind: str, window_override):
    if window_override is not None:
        return window_override
    return cfg.sliding_window


def apply_block(
    params,
    kind: str,
    x,
    positions,
    cfg,
    *,
    mode: str,
    cache=None,
    index=None,
    window_override=None,
    impl: str = "ref",
):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = cache
    if kind in ("attn", "moe"):
        h = L.rmsnorm(params["ln1"], x, cfg.norm_eps)
        w = _attn_window(cfg, kind, window_override)
        if mode == "decode":
            a, new_cache = attn_lib.decode_attention(
                params["attn"], h, cache, index, positions, cfg, window=w
            )
        else:
            a = attn_lib.attention(params["attn"], h, positions, cfg, window=w, impl=impl)
        x = x + a
        x = constrain(x, "act_batch", "act_seq", None)
        h = L.rmsnorm(params["ln2"], x, cfg.norm_eps)
        if kind == "moe":
            m, aux = moe_lib.moe_mlp(params["moe"], h, cfg, impl=MOE_IMPL[0])
        else:
            m = L.mlp(params["mlp"], h, cfg.act)
        x = x + m
    elif kind == "rec":
        h = L.rmsnorm(params["ln1"], x, cfg.norm_eps)
        if mode == "decode":
            r, new_cache = rglru_lib.rglru_decode_step(params["rec"], h, cache, cfg)
        else:
            r, new_cache = rglru_lib.rglru_block(params["rec"], h, cfg, state=None, impl=impl)
        x = x + r
        h = L.rmsnorm(params["ln2"], x, cfg.norm_eps)
        x = x + L.mlp(params["mlp"], h, cfg.act)
    elif kind == "ssd":
        h = L.rmsnorm(params["ln"], x, cfg.norm_eps)
        if mode == "decode":
            s, new_cache = ssm_lib.ssd_decode_step(params["ssd"], h, cache, cfg)
        else:
            s, new_cache = ssm_lib.ssd_block(params["ssd"], h, cfg)
        x = x + s
    else:
        raise ValueError(kind)
    x = constrain(x, "act_batch", "act_seq", None)
    return x, new_cache, aux


def init_block_cache(cfg, kind: str, batch: int, cache_len: int, window_override=None):
    if kind in ("attn", "moe"):
        w = _attn_window(cfg, kind, window_override)
        clen = min(cache_len, w) if w else cache_len
        return attn_lib.init_cache(cfg, batch, clen)
    if kind == "rec":
        return rglru_lib.init_rglru_cache(cfg, batch)
    if kind == "ssd":
        return ssm_lib.init_ssd_cache(cfg, batch)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Segmented stack
# ---------------------------------------------------------------------------


def init_stack(key, cfg):
    """Returns a list of per-segment stacked param trees (with ParamMeta)."""
    segs = cfg.segments()
    out = []
    for si, (kinds, reps) in enumerate(segs):
        kseg = jax.random.fold_in(key, si)

        def one(k, kinds=kinds):
            ks = jax.random.split(k, len(kinds))
            return {f"b{i}": init_block(ks[i], cfg, kind) for i, kind in enumerate(kinds)}

        stacked = jax.vmap(one)(jax.random.split(kseg, reps))
        out.append(add_axis(stacked, "layers"))
    return out


def stack_cache(cfg, batch: int, cache_len: int, window_override=None):
    """Caches mirroring the segment structure (stacked over repeats)."""
    segs = cfg.segments()
    out = []
    for kinds, reps in segs:
        one = {
            f"b{i}": init_block_cache(cfg, kind, batch, cache_len, window_override)
            for i, kind in enumerate(kinds)
        }
        out.append(jax.tree.map(lambda x: jnp.broadcast_to(x, (reps,) + x.shape).copy(), one))
    return out


def apply_stack(
    stack_params,
    cfg,
    x,
    positions,
    *,
    mode: str,
    caches=None,
    index=None,
    window_override=None,
    remat: str = "full",
    impl: str = "ref",
    remat_group: int = 1,
):
    """Run all segments.  Returns (x, new_caches, aux_total).

    ``remat_group`` > 1 (train only): checkpoint every g scan iterations
    instead of every one — an outer scan over reps//g rematerialised groups
    with an inner unrolled-by-scan group.  Saved residual carries shrink by
    g× at no extra recompute beyond full remat (EXPERIMENTS.md §Perf A4).
    """
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = []
    for si, (kinds, reps) in enumerate(cfg.segments()):
        p = stack_params[si]
        c = caches[si] if caches is not None else None
        g = remat_group if (mode == "train" and remat_group > 1
                            and reps % remat_group == 0) else 1

        def body(carry, xs, kinds=kinds):
            h, aux = carry
            if mode == "decode":
                pl, cl = xs
            else:
                pl, cl = xs, None
            new_cl = {}
            for i, kind in enumerate(kinds):
                blk_cache = cl[f"b{i}"] if cl is not None else None
                h, nc, a = apply_block(
                    pl[f"b{i}"],
                    kind,
                    h,
                    positions,
                    cfg,
                    mode=mode,
                    cache=blk_cache,
                    index=index,
                    window_override=window_override,
                    impl=impl,
                )
                new_cl[f"b{i}"] = nc
                aux = aux + a
            return (h, aux), (new_cl if mode == "decode" else None)

        if g > 1:
            # group g scan iterations under one checkpoint
            inner = body

            def body(carry, xs_g, inner=inner):
                return jax.lax.scan(inner, carry, xs_g)

            p = jax.tree.map(
                lambda t: t.reshape((reps // g, g) + t.shape[1:]), p
            )

        if mode == "train" and remat != "none":
            policy = None
            if remat == "dots":
                policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            body = jax.checkpoint(body, policy=policy, prevent_cse=True)

        xs = (p, c) if mode == "decode" else p
        (x, aux_total), ys = jax.lax.scan(body, (x, aux_total), xs)
        new_caches.append(ys)
    return x, (new_caches if mode == "decode" else None), aux_total


# ---------------------------------------------------------------------------
# Full decoder-only model
# ---------------------------------------------------------------------------


def init_lm_meta(key, cfg):
    """Full LM parameter tree as ParamMeta (value + logical axes)."""
    ke, ks, kh, kn = jax.random.split(key, 4)
    pv = padded_vocab(cfg)
    meta: Dict[str, Any] = {
        "embed": {
            "table": pm(L.normal_init(ke, (pv, cfg.d_model), jnp.dtype(cfg.dtype), 0.02),
                        "vocab", "embed")
        },
        "final_ln": L.init_rmsnorm(kn, cfg.d_model, cfg),
        "stack": init_stack(ks, cfg),
    }
    if not cfg.tie_embeddings:
        meta["head"] = {
            "w": pm(
                L.normal_init(kh, (cfg.d_model, pv), jnp.dtype(cfg.dtype), 0.02),
                "embed", "vocab",
            )
        }
    return meta


def init_lm(key, cfg):
    """Returns (params values, logical axes) for the decoder-only LM."""
    return split_meta(init_lm_meta(key, cfg))


def lm_logits(params, cfg, x):
    x = L.rmsnorm(params["final_ln"], x, cfg.norm_eps)
    if "head" in params:
        logits = jnp.einsum(
            "bsd,dv->bsv", x.astype(jnp.float32), params["head"]["w"].astype(jnp.float32)
        )
    else:
        logits = L.unembed(params["embed"], x)
    # mask padded vocab entries out of the softmax
    pv, v = logits.shape[-1], cfg.vocab_size
    if pv != v:
        neg = jnp.full((pv - v,), -1e30, logits.dtype)
        logits = jnp.concatenate(
            [logits[..., :v], jnp.broadcast_to(neg, logits.shape[:-1] + (pv - v,))], axis=-1
        )
    return constrain(logits, "act_batch", None, "vocab")


def lm_forward(
    params,
    cfg,
    tokens,
    positions=None,
    *,
    extra_embeds=None,
    mode: str = "train",
    remat: str = "full",
    window_override=None,
    impl: str = "ref",
    last_only: bool = False,
    remat_group: int = 1,
):
    """Train/prefill forward.  tokens: [B,S] int32.

    extra_embeds: optional [B,S_front,d] frontend embeddings (VLM patches /
    audio frames) prepended to the token embeddings.
    ``last_only``: emit logits for the final position only (serving prefill —
    avoids materialising the [B,S,V] logits tensor).
    Returns (logits [B,S(+S_front),V] or [B,1,V], aux_loss).
    """
    x = L.embed(params["embed"], tokens)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    if positions is None:
        s = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), x.shape[:1] + (s,))
        if cfg.mrope_sections is not None:
            positions = jnp.broadcast_to(positions[..., None], positions.shape + (3,))
    x = constrain(x, "act_batch", "act_seq", None)
    x, _, aux = apply_stack(
        params["stack"], cfg, x, positions,
        mode=mode, remat=remat, window_override=window_override, impl=impl,
        remat_group=remat_group,
    )
    if last_only:
        x = x[:, -1:]
    return lm_logits(params, cfg, x), aux


def lm_decode_step(
    params, cfg, token, caches, index, positions=None, *, window_override=None
):
    """One-token decode.  token: [B,1] int32; index: [] int32.

    Returns (logits [B,1,V], new_caches).
    """
    x = L.embed(params["embed"], token)
    if positions is None:
        positions = jnp.broadcast_to(index.astype(jnp.int32), token.shape)
        if cfg.mrope_sections is not None:
            positions = jnp.broadcast_to(positions[..., None], positions.shape + (3,))
    x, new_caches, _ = apply_stack(
        params["stack"], cfg, x, positions,
        mode="decode", caches=caches, index=index, window_override=window_override,
    )
    return lm_logits(params, cfg, x), new_caches


def lm_loss(params, cfg, tokens, labels, *, remat="full", impl="ref", extra_embeds=None,
            remat_group=1):
    """Next-token cross-entropy + MoE aux.  labels: [B,S] with -100 = ignore."""
    logits, aux = lm_forward(
        params, cfg, tokens, mode="train", remat=remat, impl=impl,
        extra_embeds=extra_embeds, remat_group=remat_group,
    )
    if extra_embeds is not None:
        logits = logits[:, extra_embeds.shape[1]:]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = (lse - gold) * mask
    loss = jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss + aux


def lm_axes(cfg):
    """Logical axes tree matching init_lm output (without materialising).

    Works because ParamMeta axes are static pytree aux-data: eval_shape only
    abstracts the values.
    """
    meta = jax.eval_shape(lambda k: init_lm_meta(k, cfg), jax.random.key(0))
    return split_meta(meta)[1]


def lm_param_shapes(cfg):
    """ShapeDtypeStruct tree of the LM parameters (no allocation)."""
    meta = jax.eval_shape(lambda k: init_lm_meta(k, cfg), jax.random.key(0))
    return split_meta(meta)[0]
