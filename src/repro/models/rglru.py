"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)            recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            input gate
    a_t = exp(-c * softplus(Λ) * r_t)       learned decay, c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t²) * (i_t ⊙ x_t)

Train path uses ``jax.lax.associative_scan`` over the sequence (log-depth on
TPU); decode is the single-step recurrence.  The full residual block is
    x -> [W_in -> causal conv(4) -> RG-LRU] ⊙ gelu(W_gate x) -> W_out
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import fan_in_init
from repro.models.sharding import pm

_C = 8.0


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def lru_width(cfg):
    return cfg.lru_width or cfg.d_model


def init_rglru(key, cfg):
    d = cfg.d_model
    w = lru_width(cfg)
    dt = _dtype(cfg)
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    return {
        "w_in": pm(fan_in_init(k1, (d, w), dt), "embed", "mlp"),
        "w_gate": pm(fan_in_init(k2, (d, w), dt), "embed", "mlp"),
        "conv_w": pm(fan_in_init(k3, (cfg.conv_width, w), dt), None, "mlp"),
        "conv_b": pm(jnp.zeros((w,), dt), "mlp"),
        # RG-LRU gates (diagonal parameterisation)
        "wa": pm(fan_in_init(k4, (w, w), jnp.float32), "mlp", None),
        "ba": pm(jnp.zeros((w,), jnp.float32), None),
        "wx": pm(fan_in_init(k5, (w, w), jnp.float32), "mlp", None),
        "bx": pm(jnp.zeros((w,), jnp.float32), None),
        # Λ init so that a ≈ uniform(0.9, 0.999) at r=1 (paper §2.4)
        "lam": pm(
            jnp.log(jnp.expm1(-jnp.log(jnp.linspace(0.9, 0.999, w)) / _C)).astype(jnp.float32),
            None,
        ),
        "w_out": pm(fan_in_init(k6, (w, d), dt), "mlp", "embed"),
    }


def _gates(params, x):
    """x: [b, l, w] (f32) -> (a_t [b,l,w], gated input [b,l,w])."""
    r = jax.nn.sigmoid(jnp.einsum("blw,wv->blv", x, params["wa"]) + params["ba"])
    i = jax.nn.sigmoid(jnp.einsum("blw,wv->blv", x, params["wx"]) + params["bx"])
    log_a = -_C * jax.nn.softplus(params["lam"]) * r  # [b,l,w], <= 0
    a = jnp.exp(log_a)
    x_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * x)
    return a, x_in


def rglru_scan(a, x_in, h0=None):
    """Linear recurrence h_t = a_t h_{t-1} + x_t via associative scan.

    a, x_in: [b, l, w]; h0: [b, w] or None. Returns (h [b,l,w], h_last [b,w]).
    """
    if h0 is not None:
        x_in = x_in.at[:, 0].add(a[:, 0] * h0)

    def combine(lhs, rhs):
        a1, x1 = lhs
        a2, x2 = rhs
        return a1 * a2, a2 * x1 + x2

    a_c, h = jax.lax.associative_scan(combine, (a, x_in), axis=1)
    return h, h[:, -1]


def _causal_conv(x, w, b, state=None):
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    new_state = xp[:, -(k - 1):, :] if k > 1 else None
    out = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(k)) + b
    return out, new_state


def rglru_block(params, x, cfg, state=None, impl: str = "ref"):
    """Full recurrent residual-branch.  x: [b, l, d] -> ([b, l, d], cache).

    cache = {"h": [b, w] f32, "conv": [b, k-1, w]}
    """
    gate = jax.nn.gelu(jnp.einsum("bld,dw->blw", x, params["w_gate"]))
    u = jnp.einsum("bld,dw->blw", x, params["w_in"])
    conv_state = state["conv"] if state is not None else None
    u, new_conv = _causal_conv(u, params["conv_w"], params["conv_b"], conv_state)
    uf = u.astype(jnp.float32)
    a, x_in = _gates(params, uf)
    h0 = state["h"] if state is not None else None
    if impl == "flash":
        from repro.kernels import ops as kops

        h, h_last = kops.rglru_scan(a, x_in, h0)
    else:
        h, h_last = rglru_scan(a, x_in, h0)
    y = h.astype(x.dtype) * gate
    out = jnp.einsum("blw,wd->bld", y, params["w_out"])
    return out, {"h": h_last, "conv": new_conv}


def rglru_decode_step(params, x, cache, cfg):
    """One-token step.  x: [b, 1, d]."""
    gate = jax.nn.gelu(jnp.einsum("bld,dw->blw", x, params["w_gate"]))
    u = jnp.einsum("bld,dw->blw", x, params["w_in"])
    u, new_conv = _causal_conv(u, params["conv_w"], params["conv_b"], cache["conv"])
    uf = u.astype(jnp.float32)
    a, x_in = _gates(params, uf)
    h = a[:, 0] * cache["h"] + x_in[:, 0]  # [b, w]
    y = h[:, None].astype(x.dtype) * gate
    out = jnp.einsum("blw,wd->bld", y, params["w_out"])
    return out, {"h": h, "conv": new_conv}


def init_rglru_cache(cfg, batch):
    w = lru_width(cfg)
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), jnp.bfloat16),
    }
