"""GQA attention: prefill/train (full-causal or sliding-window) and
single-token decode against a (possibly rolling) KV cache.

The default path is pure jnp (XLA) — this is what the multi-pod dry-run
lowers, since Mosaic kernels cannot lower on the CPU host backend.  The
Pallas flash kernels in ``repro.kernels`` implement the same contract and are
validated against these functions (``attention_impl="flash"`` selects them
where supported).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.layers import apply_mrope, apply_rope, dense, init_dense

NEG_INF = -1e30


def init_attention(key, cfg, d_model: Optional[int] = None):
    d = d_model or cfg.d_model
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    b = cfg.qkv_bias
    return {
        "wq": init_dense(kq, d, cfg.n_heads * hd, cfg, axes=("embed", "heads"), bias=b),
        "wk": init_dense(kk, d, cfg.n_kv_heads * hd, cfg, axes=("embed", "kv"), bias=b),
        "wv": init_dense(kv, d, cfg.n_kv_heads * hd, cfg, axes=("embed", "kv"), bias=b),
        "wo": init_dense(ko, cfg.n_heads * hd, d, cfg, axes=("heads", "embed")),
    }


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _rope(q, k, positions, cfg):
    if cfg.mrope_sections is not None:
        # positions: [..., seq, 3]
        q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
    elif positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k


def _sdpa(q, k, v, mask):
    """q:[B,S,Hq,hd] k/v:[B,T,Hkv,hd] mask:[B,1,S,T] or broadcastable."""
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    q = q.reshape(b, s, hkv, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", q.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores / math.sqrt(hd)
    if mask is not None:
        scores = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, hq, hd).astype(v.dtype)


def causal_mask(s: int, window: Optional[int] = None):
    """[1,1,S,S] boolean mask; sliding window if requested."""
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    m = j <= i
    if window is not None:
        m = m & (j > i - window)
    return m[None, None]


def attention(params, x, positions, cfg, *, window=None, impl: str = "ref"):
    """Full-sequence (train / prefill) self-attention.

    x: [B,S,d]; positions: [B,S] (or [B,S,3] for M-RoPE).
    Returns [B,S,d].
    """
    hd = cfg.resolved_head_dim
    q = _split_heads(dense(params["wq"], x), cfg.n_heads, hd)
    k = _split_heads(dense(params["wk"], x), cfg.n_kv_heads, hd)
    v = _split_heads(dense(params["wv"], x), cfg.n_kv_heads, hd)
    q, k = _rope(q, k, positions, cfg)
    if impl == "flash":
        from repro.kernels import ops as kops

        out = kops.flash_attention(q, k, v, causal=True, window=window)
    else:
        out = _sdpa(q, k, v, causal_mask(x.shape[1], window))
    return dense(params["wo"], out.reshape(out.shape[:2] + (-1,)))


def encoder_attention(params, x, positions, cfg):
    """Bidirectional self-attention (audio encoder)."""
    hd = cfg.resolved_head_dim
    q = _split_heads(dense(params["wq"], x), cfg.n_heads, hd)
    k = _split_heads(dense(params["wk"], x), cfg.n_kv_heads, hd)
    v = _split_heads(dense(params["wv"], x), cfg.n_kv_heads, hd)
    q, k = _rope(q, k, positions, cfg)
    out = _sdpa(q, k, v, None)
    return dense(params["wo"], out.reshape(out.shape[:2] + (-1,)))


def cross_attention(params, x, enc_kv, cfg):
    """Decoder->encoder cross attention.  enc_kv: (k, v) precomputed
    [B,T,Hkv,hd] pair (computed once at prefill from encoder output)."""
    hd = cfg.resolved_head_dim
    q = _split_heads(dense(params["wq"], x), cfg.n_heads, hd)
    k, v = enc_kv
    out = _sdpa(q, k, v, None)
    return dense(params["wo"], out.reshape(out.shape[:2] + (-1,)))


def project_enc_kv(params, enc_out, cfg):
    hd = cfg.resolved_head_dim
    k = _split_heads(dense(params["wk"], enc_out), cfg.n_kv_heads, hd)
    v = _split_heads(dense(params["wv"], enc_out), cfg.n_kv_heads, hd)
    return k, v


# ---------------------------------------------------------------------------
# Decode (one new token against a KV cache)
# ---------------------------------------------------------------------------


def decode_attention(params, x, cache, index, positions, cfg, *, window=None):
    """One-step decode.

    x: [B,1,d] current token hidden states.
    cache: dict(k=[B,C,Hkv,hd], v=[B,C,Hkv,hd]) where C = full seq_len for
        dense attention or the rolling window size for SWA.
    index: [] int32 — number of tokens already in context.
    positions: [B,1] (or [B,1,3]) position ids of the new token.
    Returns (out [B,1,d], new_cache).
    """
    hd = cfg.resolved_head_dim
    q = _split_heads(dense(params["wq"], x), cfg.n_heads, hd)
    k_new = _split_heads(dense(params["wk"], x), cfg.n_kv_heads, hd)
    v_new = _split_heads(dense(params["wv"], x), cfg.n_kv_heads, hd)
    q, k_new = _rope(q, k_new, positions, cfg)

    cache_len = cache["k"].shape[1]
    slot = index % cache_len if window is not None else index
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0))

    # valid positions: cache slots holding tokens <= index
    j = jnp.arange(cache_len)
    if window is None:
        valid = j <= index
    else:
        # rolling buffer: before wrap-around only slots <= index hold tokens;
        # once index >= cache_len every slot holds a token in the window.
        valid = (j <= index) | (index >= cache_len)
    mask = valid[None, None, None, :]  # [1,1,1,C]

    b, s, hq, _ = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qs = q.reshape(b, s, hkv, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qs.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores / math.sqrt(hd)
    scores = jnp.where(mask[:, :, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    out = out.reshape(b, s, hq, hd).astype(x.dtype)
    out = dense(params["wo"], out.reshape(b, s, -1))
    return out, {"k": k, "v": v}


def init_cache(cfg, batch, cache_len, dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    shape = (batch, cache_len, cfg.n_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
