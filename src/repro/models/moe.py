"""Mixture-of-Experts layer (GShard/Switch-style dense einsum dispatch).

TPU-native formulation: routing + capacity-bounded one-hot dispatch expressed
as einsums so GSPMD turns the expert dimension (sharded over the ``model``
mesh axis) into all-to-all / all-gather collectives — no per-expert gather
loops.  Supports top-1 (Llama-4 Maverick) and top-2 (Phi-3.5-MoE) routing
with a load-balance auxiliary loss.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import fan_in_init
from repro.models.sharding import pm


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def init_moe(key, cfg):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = _dtype(cfg)
    kr, ki, kg, ko = jax.random.split(key, 4)
    p = {
        "router": pm(fan_in_init(kr, (d, e), jnp.float32), "embed", None),
        "wi": pm(fan_in_init(ki, (e, d, f), dt), "experts", "embed", "mlp"),
        "wo": pm(fan_in_init(ko, (e, f, d), dt, fan_in=f), "experts", "mlp", "embed"),
    }
    if cfg.act in ("swiglu", "geglu"):
        p["wg"] = pm(fan_in_init(kg, (e, d, f), dt), "experts", "embed", "mlp")
    return p


def _capacity(cfg, tokens_per_group: int) -> int:
    cap = int(cfg.capacity_factor * tokens_per_group * cfg.experts_per_token / cfg.n_experts)
    return max(cap, cfg.experts_per_token, 1)


def route(router_w, x, cfg) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Top-k routing with capacity.

    x: [b, s, d] -> (dispatch [b,s,e,c] bool, combine [b,s,e,c] f32, aux loss).
    """
    b, s, _ = x.shape
    e = cfg.n_experts
    k = cfg.experts_per_token
    c = _capacity(cfg, s)
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), router_w)
    gates = jax.nn.softmax(logits, axis=-1)

    # iterative top-k expert choice
    masks = []
    gvals = []
    g = gates
    for _ in range(k):
        idx = jnp.argmax(g, axis=-1)  # [b,s]
        m = jax.nn.one_hot(idx, e, dtype=jnp.float32)
        masks.append(m)
        gvals.append(jnp.sum(g * m, axis=-1))
        g = g * (1.0 - m)

    # load-balance aux loss (Switch): e * sum_e fraction_e * prob_e
    frac = jnp.mean(masks[0], axis=(0, 1))
    prob = jnp.mean(gates, axis=(0, 1))
    aux = e * jnp.sum(frac * prob) * cfg.router_aux_coef

    # capacity assignment: position of each token in its expert's queue
    dispatch = jnp.zeros((b, s, e, c), jnp.float32)
    combine = jnp.zeros((b, s, e, c), jnp.float32)
    prior = jnp.zeros((b, 1, e), jnp.float32)
    for m, gv in zip(masks, gvals):
        pos = jnp.cumsum(m, axis=1) - m + prior  # [b,s,e]
        keep = (pos < c) * m
        prior = prior + jnp.sum(m, axis=1, keepdims=True)
        pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), c, dtype=jnp.float32)  # [b,s,e,c]
        dispatch = dispatch + keep[..., None] * pos_oh
        combine = combine + (keep * gv[..., None])[..., None] * pos_oh

    # renormalise top-k gates over the kept experts
    denom = jnp.sum(combine, axis=(2, 3), keepdims=True)
    combine = combine / jnp.maximum(denom, 1e-9)
    return dispatch, combine, aux


def moe_mlp(params, x, cfg, impl: str = "einsum"):
    """x: [b, s, d] -> ([b, s, d], aux_loss).

    impl="einsum": GShard one-hot dispatch (baseline — all-MXU, but the
    dispatch/combine einsums cost O(B·S·E·C·d) FLOPs, comparable to the
    expert matmuls themselves for top-1/128-expert configs).
    impl="scatter": index-based dispatch — scatter tokens into the expert
    buffers and gather the results back; removes the E×C one-hot contraction
    entirely (EXPERIMENTS.md §Perf C1).
    """
    if impl == "scatter":
        return _moe_mlp_scatter(params, x, cfg)
    dispatch, combine, aux = route(params["router"], x, cfg)
    # dispatch tokens to expert buffers: [e, b, c, d]
    xe = jnp.einsum("bsec,bsd->ebcd", dispatch.astype(x.dtype), x)
    h = jnp.einsum("ebcd,edf->ebcf", xe, params["wi"])
    if "wg" in params:
        act = jax.nn.silu if cfg.act == "swiglu" else jax.nn.gelu
        h = act(jnp.einsum("ebcd,edf->ebcf", xe, params["wg"])) * h
    else:
        h = jax.nn.gelu(h)
    ye = jnp.einsum("ebcf,efd->ebcd", h, params["wo"])
    y = jnp.einsum("bsec,ebcd->bsd", combine.astype(x.dtype), ye)
    return y, aux


def _experts_forward(params, xe, cfg):
    """xe: [e, b, c, d] -> [e, b, c, d] through the per-expert MLPs."""
    h = jnp.einsum("ebcd,edf->ebcf", xe, params["wi"])
    if "wg" in params:
        act = jax.nn.silu if cfg.act == "swiglu" else jax.nn.gelu
        h = act(jnp.einsum("ebcd,edf->ebcf", xe, params["wg"])) * h
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("ebcf,efd->ebcd", h, params["wo"])


def _moe_mlp_scatter(params, x, cfg):
    """Scatter/gather dispatch: no O(E·C) one-hot contractions.

    Routing (top-k choice, capacity positions, aux loss) is identical to
    :func:`route`; only the token movement changes: tokens are scattered
    into [e, b, cap, d] buffers with ``.at[].add`` and results gathered back
    with ``take_along_axis`` — O(tokens·d) data movement, zero MXU flops.
    """
    b, s, _ = x.shape
    e = cfg.n_experts
    k = cfg.experts_per_token
    c = _capacity(cfg, s)
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    gates = jax.nn.softmax(logits, axis=-1)

    masks, gvals, idxs = [], [], []
    g = gates
    for _ in range(k):
        idx = jnp.argmax(g, axis=-1)
        m = jax.nn.one_hot(idx, e, dtype=jnp.float32)
        masks.append(m)
        gvals.append(jnp.sum(g * m, axis=-1))
        idxs.append(idx)
        g = g * (1.0 - m)

    frac = jnp.mean(masks[0], axis=(0, 1))
    prob = jnp.mean(gates, axis=(0, 1))
    aux = e * jnp.sum(frac * prob) * cfg.router_aux_coef

    # capacity positions per (token, choice): cumsum of the one-hot masks
    xe = jnp.zeros((e, b, c, x.shape[-1]), x.dtype)
    prior = jnp.zeros((b, 1, e), jnp.float32)
    keeps, poss = [], []
    for m in masks:
        pos = jnp.cumsum(m, axis=1) - m + prior          # [b,s,e]
        prior = prior + jnp.sum(m, axis=1, keepdims=True)
        pos_tok = jnp.sum(pos * m, axis=-1).astype(jnp.int32)  # [b,s]
        keep = (pos_tok < c) & (jnp.sum(m, axis=-1) > 0)
        keeps.append(keep)
        poss.append(jnp.where(keep, pos_tok, c - 1))

    bi = jnp.arange(b)[:, None] * jnp.ones((1, s), jnp.int32)
    for idx, keep, pos in zip(idxs, keeps, poss):
        contrib = jnp.where(keep[..., None], x, 0)
        xe = xe.at[idx, bi, pos].add(contrib)

    ye = _experts_forward(params, xe, cfg)

    # gather back + gate-weighted combine (renormalised over kept experts)
    outs, weights = [], []
    for idx, keep, pos, gv in zip(idxs, keeps, poss, gvals):
        got = ye[idx, bi, pos]                           # [b,s,d]
        w = gv * keep
        outs.append(got * w[..., None].astype(got.dtype))
        weights.append(w)
    denom = jnp.maximum(sum(weights), 1e-9)[..., None].astype(x.dtype)
    y = sum(outs) / denom
    return y, aux
