"""End-to-end FL driver for the anomaly-detection use case (paper §V).

Runs the full Algorithm-1 loop on the (synthetic stand-in) UNSW-NB15 / ROAD
federations with the paper's detector MLP, producing the metrics the paper
reports: accuracy, AUC-ROC and (simulated) training time, for our method and
the baselines.

Methods:
  proposed        — adaptive utility selection + DP + fault tolerance (ours)
  proposed_noft   — ours without fault tolerance      (Table II ablation)
  acfl            — ACFL-style uncertainty (active) selection [5]-lite
  fedl2p          — FedAvg + per-client personalisation fine-tuning [11]-lite
  random          — plain FedAvg with random selection
  adafl           — AdaFL-style history-weighted selection [3]-lite
  power_of_choice — power-of-choice selection

Time model (the container has one CPU; the paper measured a GPU workstation):
simulated round time = slowest selected client's local compute
(steps × base_step_time / compute_capacity_i) + communication + DP overhead
+ checkpoint writes + Weibull-expected recovery — every term is derived from
the same FLConfig/fault model the rest of the framework uses, so *relative*
times across methods are meaningful (EXPERIMENTS.md reports those).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core import rounds as rounds_lib
from repro.core.fault import optimal_checkpoint_interval
from repro.data.synthetic import FederatedData, round_batches
from repro.models import mlp as mlp_lib

METHODS = ("proposed", "proposed_noft", "acfl", "fedl2p", "random", "adafl",
           "power_of_choice")


def fl_for_method(base: FLConfig, method: str) -> FLConfig:
    """Method-specific FLConfig tweaks (selection strategy etc.)."""
    if method == "proposed":
        return dataclasses.replace(base, selection="adaptive_utility",
                                   fault_tolerance=True)
    if method == "proposed_noft":
        return dataclasses.replace(base, selection="adaptive_utility",
                                   fault_tolerance=False)
    if method == "acfl":
        return dataclasses.replace(base, selection="acfl", adaptive_k=False)
    if method == "fedl2p":
        return dataclasses.replace(base, selection="random", adaptive_k=False)
    if method == "random":
        return dataclasses.replace(base, selection="random", adaptive_k=False)
    if method == "adafl":
        return dataclasses.replace(base, selection="adafl")
    if method == "power_of_choice":
        return dataclasses.replace(base, selection="power_of_choice",
                                   adaptive_k=False)
    raise ValueError(method)


@dataclass
class RunResult:
    method: str
    dataset: str
    seed: int
    accuracy: float
    auc: float
    sim_time_s: float
    wall_time_s: float
    rounds: int
    eps_spent: float
    history: Dict[str, List[float]] = field(default_factory=dict)

    def time_to_acc(self, target: float) -> float:
        """Simulated seconds until test accuracy first reaches ``target``
        (the paper's training-time metric is time-to-quality); inf if never."""
        for t, a in zip(self.history.get("cum_time", []), self.history.get("acc", [])):
            if a >= target:
                return t
        return float("inf")


def _personalize(params, fed: FederatedData, steps: int = 3, lr: float = 0.05,
                 batch: int = 64, seed: int = 0):
    """FedL2P-lite personalisation: a few local fine-tune steps per client;
    returns the average personalised test metrics."""
    rng = np.random.default_rng(seed)
    grad_fn = jax.jit(jax.grad(mlp_lib.mlp_loss))
    accs, scores_all, labels_all = [], [], []
    for ci in range(fed.n_clients):
        p = params
        for _ in range(steps):
            idx = rng.integers(0, len(fed.x[ci]), batch)
            b = {"x": jnp.asarray(fed.x[ci][idx]), "y": jnp.asarray(fed.y[ci][idx])}
            g = grad_fn(p, b)
            p = jax.tree.map(lambda a, gg: a - lr * gg, p, g)
        proba = mlp_lib.mlp_predict_proba(p, jnp.asarray(fed.test_x))[:, 1]
        accs.append(float(mlp_lib.accuracy(p, jnp.asarray(fed.test_x),
                                           jnp.asarray(fed.test_y))))
        scores_all.append(np.asarray(proba))
    acc = float(np.mean(accs))
    auc = mlp_lib.auc_roc(np.mean(scores_all, axis=0), fed.test_y)
    return acc, auc


def simulate_round_time(fl: FLConfig, util_state, sel_mask, failed,
                        base_step_time: float = 0.02,
                        comm_time: float = 0.35,
                        ckpt_write: float = 0.08,
                        param_kb: float = 64.0) -> float:
    """Paper-faithful wall-time model for one round (see module docstring)."""
    sel = np.asarray(sel_mask) > 0
    if not sel.any():
        return comm_time
    capacity = np.asarray(util_state.compute)[sel]
    steps = fl.local_epochs
    compute = steps * base_step_time / np.maximum(capacity, 0.1)
    slowest = float(np.max(compute))
    t = slowest + comm_time * (1.0 + param_kb / 1024.0)
    if fl.dp_enabled:
        t += 0.01  # clip+noise pass
    if fl.fault_tolerance:
        t += ckpt_write * max(1, steps // 2)
        t += float(np.asarray(failed)[sel].sum()) * fl.recovery_time * 0.01
    else:
        # failed clients redo the whole round next time: amortised penalty
        t += float(np.asarray(failed)[sel].sum()) * slowest
    return t


def run_fl(
    fed: FederatedData,
    fl: FLConfig,
    method: str = "proposed",
    seed: int = 0,
    rounds: Optional[int] = None,
    eval_every: int = 10,
    dataset: str = "unsw",
    hidden: int = 64,
) -> RunResult:
    fl = fl_for_method(fl, method)
    rounds = rounds or fl.rounds
    rng = np.random.default_rng(seed)
    key = jax.random.key(seed)

    params = mlp_lib.init_mlp(jax.random.fold_in(key, 0), fed.n_features,
                              hidden, fed.n_classes)
    sizes = fed.data_sizes()
    state = rounds_lib.init_round_state(
        params, fl, jax.random.fold_in(key, 1), n_clients=fed.n_clients,
        data_size=jnp.asarray(sizes / sizes.mean()),
        data_quality=jnp.asarray(fed.label_entropy()),
    )
    round_step = jax.jit(
        rounds_lib.make_parallel_round(mlp_lib.mlp_loss, fl, fed.n_clients)
    )

    tx, ty = jnp.asarray(fed.test_x), jnp.asarray(fed.test_y)
    history = {"round": [], "loss": [], "acc": [], "auc": [], "k": [],
               "cum_time": []}
    sim_time = 0.0
    t0 = time.time()
    for r in range(rounds):
        batches = jax.tree.map(
            jnp.asarray, round_batches(rng, fed, fl.local_epochs, fl.local_batch)
        )
        state, metrics = round_step(state, batches)
        sim_time += simulate_round_time(fl, state.util, metrics.sel_mask,
                                        metrics.failed)
        if (r + 1) % eval_every == 0 or r == rounds - 1:
            acc = float(mlp_lib.accuracy(state.params, tx, ty))
            proba = np.asarray(mlp_lib.mlp_predict_proba(state.params, tx)[:, 1])
            auc = mlp_lib.auc_roc(proba, fed.test_y)
            history["round"].append(r + 1)
            history["loss"].append(float(metrics.global_loss))
            history["acc"].append(acc)
            history["auc"].append(auc)
            history["k"].append(float(metrics.k_effective))
            history["cum_time"].append(sim_time)

    acc, auc = history["acc"][-1], history["auc"][-1]
    if method == "fedl2p":
        # personalisation pass (the point of FedL2P) + its simulated cost
        acc, auc = _personalize(state.params, fed, seed=seed)
        sim_time *= 1.2
    # DP budget actually spent (RDP accountant over the executed rounds)
    from repro.core import dp as dp_lib

    eps = 0.0
    if fl.dp_enabled:
        sigma = (fl.dp_sigma if fl.dp_mode == "paper"
                 else dp_lib.gaussian_sigma(fl.dp_epsilon, fl.dp_delta, fl.dp_clip))
        acct = dp_lib.RdpAccountant(fl.dp_delta)
        q = fl.clients_per_round / fl.n_clients
        for _ in range(rounds):
            acct.step(max(sigma / max(fl.dp_clip, 1e-9), 1e-3), q)
        eps = acct.epsilon()

    return RunResult(
        method=method, dataset=dataset, seed=seed,
        accuracy=acc, auc=auc,
        sim_time_s=sim_time, wall_time_s=time.time() - t0,
        rounds=rounds, eps_spent=eps, history=history,
    )
