"""End-to-end FL driver for the anomaly-detection use case (paper §V).

Runs the full Algorithm-1 loop on the (synthetic stand-in) UNSW-NB15 / ROAD
federations, producing the metrics the paper reports: accuracy, AUC-ROC and
(simulated) training time, for our method and the baselines.

The detector architecture is pluggable (ISSUE 4): every model-touching
site — init, per-client loss, test-set prediction, metrics, FedL2P
personalisation — goes through the :class:`~repro.models.spec.ModelSpec`
resolved from the STATIC ``FLConfig.model`` field (``mlp`` — the paper's
detector, default — or the window-native ROAD detectors ``cnn``/``rglru``).
Model choice rides the runner-cache statics key, so each architecture
compiles once and shares the sweep/privacy machinery unchanged.

Methods:
  proposed        — adaptive utility selection + DP + fault tolerance (ours)
  proposed_noft   — ours without fault tolerance      (Table II ablation)
  acfl            — ACFL-style uncertainty (active) selection [5]-lite
  fedl2p          — FedAvg + per-client personalisation fine-tuning [11]-lite
  random          — plain FedAvg with random selection
  adafl           — AdaFL-style history-weighted selection [3]-lite
  power_of_choice — power-of-choice selection

Execution engines (docs/ARCHITECTURE.md):

* :func:`run_fl_sweep` — the COMPILED sweep engine.  The whole round loop
  is one ``jax.lax.scan`` (batch sampling, round step, time model and eval
  all lowered), ``jax.vmap``-ed over a **seed×config lane axis**: every
  scalar hyper-parameter (``FLParams``) is a runtime array, so an entire
  ε/failure/lr grid × repeated trials runs as ONE program, compiled once
  per (method statics, shapes) and sharded over the available devices.
  There is no host sync until the final history readback.
* :func:`run_fl` / :func:`run_fl_batch` — single-cell front doors of the
  same engine (a sweep of one config; a batch of one seed).
* Scheduled-budget privacy (``FLConfig.dp_scheduled``): the privacy
  subsystem's RDP accountant + budget scheduler ride the scan carry —
  per-round σ from the scheduler, exhaustion masking via the round step's
  ``update_gate``, accounted ε in the eval trace (``repro/privacy``,
  docs/ARCHITECTURE.md §Privacy).
* Failure scenarios (``repro/fault``, docs/DESIGN.md §6): the runtime
  ``fault_process`` lane code selects iid / Markov-bursty /
  Weibull-lifetime / straggler failure processes; per-client process
  state rides in ``RoundState`` through the scan carry, stragglers feed
  per-client ``slow`` factors into :func:`simulate_round_time`, and the
  eval trace carries a ``fail`` history column.
* :func:`run_fl_legacy` — the original per-round Python loop, kept as the
  semantic oracle: tests/test_engine.py checks the scanned engine against
  it, and benchmarks/bench_engine.py records the old-vs-new rounds/sec
  comparison in BENCH_engine.json; BENCH_sweep.json records the
  sweep-vs-per-cell comparison (benchmarks/bench_sweep.py).

Time model (the container has one CPU; the paper measured a GPU workstation):
simulated round time = slowest selected client's local compute
(steps × base_step_time / compute_capacity_i) + communication + DP overhead
+ checkpoint writes + Weibull-expected recovery — every term is derived from
the same FLConfig/fault model the rest of the framework uses, so *relative*
times across methods are meaningful (EXPERIMENTS.md §Time-model reports
those).  :func:`simulate_round_time` is pure ``jnp`` so the accumulator can
ride inside the scan carry.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
import warnings
import weakref
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.base import (FLConfig, FLParams, fl_params, fl_static)
from repro.core import fault as fault_lib
from repro.core import plans as plans_lib
from repro.core import rounds as rounds_lib
from repro.core import scale as scale_lib
from repro.data.synthetic import (FederatedData, Population,
                                  StackedFederation, round_batches,
                                  sample_cohort_batches,
                                  sample_round_batches, stack_federation)
from repro.launch.mesh import make_scale_mesh
from repro.models import shardctx
from repro.models import sharding as shard_lib
from repro.obs import stats as obs_stats
from repro.obs import trace as obs_trace
from repro.models.mlp import auc_roc, auc_roc_jnp
from repro.models.spec import DataMeta, ModelSpec, get_model_spec, meta_for
from repro.privacy import accountant as acct_lib
from repro.privacy import schedule as sched_lib
from repro.privacy.accountant import accounted_epsilon

METHODS = ("proposed", "proposed_noft", "acfl", "fedl2p", "random", "adafl",
           "power_of_choice")


def fl_for_method(base: FLConfig, method: str) -> FLConfig:
    """Method-specific FLConfig tweaks (selection strategy etc.)."""
    if method == "proposed":
        return dataclasses.replace(base, selection="adaptive_utility",
                                   fault_tolerance=True)
    if method == "proposed_noft":
        return dataclasses.replace(base, selection="adaptive_utility",
                                   fault_tolerance=False)
    if method == "acfl":
        return dataclasses.replace(base, selection="acfl", adaptive_k=False)
    if method == "fedl2p":
        return dataclasses.replace(base, selection="random", adaptive_k=False)
    if method == "random":
        return dataclasses.replace(base, selection="random", adaptive_k=False)
    if method == "adafl":
        return dataclasses.replace(base, selection="adafl")
    if method == "power_of_choice":
        return dataclasses.replace(base, selection="power_of_choice",
                                   adaptive_k=False)
    raise ValueError(method)


@dataclass
class RunResult:
    method: str
    dataset: str
    seed: int
    accuracy: float
    auc: float
    sim_time_s: float
    wall_time_s: float
    rounds: int
    eps_spent: float
    history: Dict[str, List[float]] = field(default_factory=dict)
    # final global model (host numpy pytree), attached only when the caller
    # asked (run_fl*(..., return_params=True)) — the serving engine's input
    params: Optional[object] = field(default=None, repr=False)

    def time_to_acc(self, target: float) -> float:
        """Simulated seconds until test accuracy first reaches ``target``
        (the paper's training-time metric is time-to-quality); inf if never."""
        for t, a in zip(self.history.get("cum_time", []), self.history.get("acc", [])):
            if a >= target:
                return t
        return float("inf")


def personalized_client_params(params, fed: FederatedData, spec: ModelSpec,
                               steps: int = 3, lr: float = 0.05,
                               batch: int = 64, seed: int = 0) -> List:
    """FedL2P-lite fine-tuning, parameters only: a few local SGD steps per
    client from the global ``params``; returns one personalised pytree per
    client (client order).  The rng draws happen exclusively here, in
    client order, so splitting metrics out (``_personalize``) or exporting
    the params for serving (``export_personalized``) is draw-for-draw
    identical to the original fused loop."""
    rng = np.random.default_rng(seed)
    grad_fn = jax.jit(jax.grad(spec.loss))
    out = []
    for ci in range(fed.n_clients):
        p = params
        for _ in range(steps):
            idx = rng.integers(0, len(fed.x[ci]), batch)
            b = {"x": jnp.asarray(fed.x[ci][idx]), "y": jnp.asarray(fed.y[ci][idx])}
            g = grad_fn(p, b)
            p = jax.tree.map(lambda a, gg: a - lr * gg, p, g)
        out.append(p)
    return out


def export_personalized(params, fed: FederatedData, spec: ModelSpec,
                        steps: int = 3, lr: float = 0.05,
                        batch: int = 64, seed: int = 0):
    """Personalised per-client parameters STACKED along a leading client
    axis (host numpy) — the ``heads`` pytree the serving engine indexes
    with ``client=i`` and ``save_serving_checkpoint`` persists."""
    per_client = personalized_client_params(params, fed, spec, steps=steps,
                                            lr=lr, batch=batch, seed=seed)
    return jax.tree.map(lambda *leaves: np.stack([np.asarray(l) for l in leaves]),
                        *per_client)


def _personalize(params, fed: FederatedData, spec: ModelSpec,
                 steps: int = 3, lr: float = 0.05,
                 batch: int = 64, seed: int = 0):
    """FedL2P-lite personalisation: a few local fine-tune steps per client;
    returns the average personalised test metrics.  Model-generic: the
    fine-tune gradient and the test metrics come from the ``spec``."""
    per_client = personalized_client_params(params, fed, spec, steps=steps,
                                            lr=lr, batch=batch, seed=seed)
    accs, scores_all = [], []
    for p in per_client:
        proba = spec.predict_proba(p, jnp.asarray(fed.test_x))[:, 1]
        accs.append(float(spec.accuracy(p, jnp.asarray(fed.test_x),
                                        jnp.asarray(fed.test_y))))
        scores_all.append(np.asarray(proba))
    acc = float(np.mean(accs))
    auc = auc_roc(np.mean(scores_all, axis=0), fed.test_y)
    return acc, auc


def simulate_round_time(fl: FLConfig, util_state, sel_mask, failed,
                        base_step_time: float = 0.02,
                        comm_time: float = 0.35,
                        ckpt_write: float = 0.08,
                        param_kb: float = 64.0,
                        params: Optional[FLParams] = None,
                        slow=None) -> jnp.ndarray:
    """Paper-faithful wall-time model for one round (see module docstring).

    Pure ``jnp`` — jit-safe, so the cumulative simulated time is carried
    through the ``lax.scan`` state instead of syncing to NumPy every round.
    Branching on the STATIC FLConfig fields (dp_enabled, fault_tolerance)
    is fine; the recovery term reads the runtime ``params`` (defaulting to
    the config's values), so failure-model sweeps share one program.

    ``slow``: optional [n] per-client round-time stretch factors from the
    failure-scenario engine (``RoundMetrics.slow`` — the straggler process;
    all-ones on every other lane, where ``x·1.0`` is bitwise ``x``).  The
    round waits for the slowest selected client, so one straggler stretches
    the whole cohort's round — exactly the synchronous-FL pathology.

    Plan time models (DESIGN.md §4) ride the runtime ``plan_code`` lane,
    branch-free like everything else, so a mixed plan frontier shares the
    program and code-0 lanes stay bitwise the synchronous model:

    * ``buffered_async`` (code 1) — the server flushes once K =
      ``async_buffer`` updates arrive, so the round costs the K-th
      smallest per-client compute time (capped at the slowest when fewer
      than K contribute) + communication; checkpoint writes and recovery
      leave the critical path (the server never waits for dead clients).
    * ``hierarchical`` (code 2) — slowest client + two cheaper hops
      (client→edge and edge→cloud, each ``hier_comm_frac`` of the flat
      WAN hop) instead of the flat client→cloud communication.
    """
    pr = fl_params(fl) if params is None else params
    sel = sel_mask > 0
    any_sel = jnp.any(sel)
    steps = fl.local_epochs
    compute = steps * base_step_time / jnp.maximum(util_state.compute, 0.1)
    if slow is not None:
        compute = compute * slow
    slowest = jnp.max(jnp.where(sel, compute, 0.0))
    comm_full = comm_time * (1.0 + param_kb / 1024.0)

    # Synchronous chain — textually the pre-registry expression.  The plan
    # variants are selected AFTER the chain (not interleaved into it) so
    # XLA constant-folds the scalar additions exactly as it always did and
    # code-0 lanes stay bitwise (tests/test_plans.py golden pins).
    t = slowest + comm_full
    if fl.dp_enabled:
        t = t + 0.01  # clip+noise pass
    n_failed_sel = jnp.sum(jnp.where(sel, failed, 0.0))
    if fl.fault_tolerance:
        t = t + ckpt_write * max(1, steps // 2)
        t = t + n_failed_sel * fault_lib.recovery_overhead(pr.recovery_time)
    else:
        # failed clients redo the whole round next time: amortised penalty
        t = t + n_failed_sel * slowest

    # buffered_async (code 1): K-th smallest selected arrival — the same
    # per-client compute vector, straggler-stretched, so arrival ORDER is
    # the failure-scenario engine's (repro.fault.arrival_score ranks agree;
    # capped at the slowest when fewer than K contribute).  No checkpoint
    # or recovery stall: the buffer flushes without waiting on the dead.
    arrivals = jnp.sort(jnp.where(sel, compute, jnp.inf))
    k_idx = jnp.clip(pr.async_buffer, 1.0,
                     float(sel_mask.shape[0])).astype(jnp.int32) - 1
    kth = jnp.minimum(jnp.take(arrivals, k_idx), slowest)
    t_async = kth + comm_full
    if fl.dp_enabled:
        t_async = t_async + 0.01

    # hierarchical (code 2): same synchronous chain, but the flat WAN hop
    # is replaced by two edge hops each at hier_comm_frac of its cost
    t_hier = t - comm_full + 2.0 * pr.hier_comm_frac * comm_full

    t = jnp.where(pr.plan_code == 1.0, t_async,
                  jnp.where(pr.plan_code == 2.0, t_hier, t))
    return jnp.where(any_sel, t, comm_time)


def spent_epsilon(fl: FLConfig, rounds: int) -> float:
    """Deprecated alias of :func:`repro.privacy.accounted_epsilon` (PR 3).

    The accountant subsystem is the single source of ε now: fixed-σ runs
    report the closed-form composition, scheduled runs report the in-scan
    accountant's trace (``RunResult.history['eps']``)."""
    warnings.warn(
        "fl_driver.spent_epsilon is deprecated; use "
        "repro.privacy.accounted_epsilon (fixed-σ) or the in-scan "
        "accountant trace (dp_scheduled)", DeprecationWarning, stacklevel=2)
    return accounted_epsilon(fl, rounds)


# ---------------------------------------------------------------------------
# Compiled engine: lax.scan over rounds, vmap over seed×config lanes
# ---------------------------------------------------------------------------


def _eval_rounds(rounds: int, eval_every: int) -> List[int]:
    """0-based round indices the legacy loop evaluated at."""
    return [r for r in range(rounds)
            if (r + 1) % eval_every == 0 or r == rounds - 1]


def realized_cohort_fraction(k_eff, n_clients: int):
    """Sampling fraction the RDP accountant must compose at.

    ``_topk_mask`` selects every rank strictly below ``k_eff`` — for a
    fractional controller K (adaptive-K grow steps produce e.g. 7.75) that
    is ``ceil(k_eff)`` clients, so composing at ``k_eff/n`` systematically
    under-accounted ε (ISSUE 4 bugfix).  ``ceil(k_eff)/n`` is the realised
    cohort's fraction; availability masking can only select *fewer*
    clients, so this never understates the spend.
    """
    return jnp.clip(jnp.ceil(k_eff) / n_clients, 0.0, 1.0)


def _build_single_run(fl: FLConfig, rounds: int, eval_every: int,
                      meta: DataMeta):
    """``single_run(key, stack, data_size, data_quality, params) ->
    (final_params, sim_time, eval trace)``, a pure function of the seed key,
    the (runtime-argument) federation and the runtime :class:`FLParams`.

    ``fl`` here is the STATIC config (the caller canonicalises with
    ``fl_static``): every scalar hyper-parameter the round step consumes
    comes from ``params``, so vmapping this function over stacked FLParams
    lanes sweeps a whole hyper-parameter grid inside one program.  The
    detector architecture is the spec resolved from the STATIC
    ``fl.model`` against ``meta`` (models/spec.py) — init, per-client
    loss and the eval metrics all come from it, so a new architecture is
    a registry entry, not an engine change.

    Structure: a NESTED scan.  The inner ``lax.scan`` advances ``eval_every``
    rounds carrying (RoundState, data key, cumulative simulated time); the
    outer scan runs one inner block per eval point and computes test
    accuracy/AUC once per block — the same eval cadence as the legacy loop,
    so the compiled engine never pays per-round eval (the test-set forward +
    rank-AUC argsort are ~half a round's compute).  A trailing partial block
    handles ``rounds % eval_every`` so the final round is always evaluated.

    Scheduled-budget configs (``fl.dp_scheduled``, STATIC) extend the carry
    with the privacy subsystem's state: an in-scan RDP
    :class:`~repro.privacy.accountant.AccountantState` and a
    :class:`~repro.privacy.schedule.SchedulerState`.  Every round the
    scheduler emits σ_t, the accountant tentatively composes the release at
    the REALISED cohort fraction q_t = ceil(k_eff)/n (adaptive K changes
    the subsampling amplification and the accountant sees it; the top-k
    mask selects ceil of the controller's fractional K — see
    :func:`realized_cohort_fraction`), and a release
    that would push ε past ``pr.dp_budget`` is withheld via the round
    step's ``update_gate`` — the global model freezes bitwise at budget
    exhaustion.  ε is converted from the carried RDP curve on eval
    boundaries only and emitted into the trace (``eps``/``sigma``/``live``
    history columns); the scheduler's stall controller also updates there,
    from the same AUC the eval computes anyway.
    """
    n_full, rem = divmod(rounds, eval_every)
    scheduled = fl.dp_enabled and fl.dp_scheduled
    if scheduled and fl.dp_mode != "clipped":
        raise ValueError(
            "dp_scheduled requires dp_mode='clipped': the accountant "
            "composes z_t = sigma_t/dp_clip, which is only a valid "
            "(epsilon, delta) statement when updates are clipped to "
            "dp_clip — the paper's unclipped fixed-sigma mode has "
            "unbounded sensitivity")

    spec = get_model_spec(fl.model, meta)

    # ``fl`` is canonicalised (fl_static: plan → program family), so the
    # registry hands back the family's round builder; plans outside the
    # client_parallel family have their own drivers and fail loudly here
    # instead of silently running the wrong program (pre-registry, a
    # client_serial config fell through to the parallel round step).
    plan = plans_lib.get_plan(fl.plan)
    if plan.family != "client_parallel":
        raise ValueError(
            f"the compiled sweep engine runs the 'client_parallel' program "
            f"family; plan {fl.plan!r} (family {plan.family!r}) is not "
            "driver-capable — see the core/plans registry for its engine")
    builder = plan.builder_fn()

    def single_run(key, stack: StackedFederation, data_size, data_quality,
                   pr: FLParams):
        n_clients = stack.n_clients
        round_step = builder(spec.loss, fl, n_clients)
        tx, ty = stack.test_x, stack.test_y
        k_static = jnp.asarray(float(fl.clients_per_round), jnp.float32)

        def one_round(carry, _):
            if scheduled:
                state, data_key, cum_time, acct, sched = carry
            else:
                state, data_key, cum_time = carry
            data_key, k_batch = jax.random.split(data_key)
            batches = sample_round_batches(k_batch, stack, fl.local_epochs,
                                           fl.local_batch)
            if scheduled:
                k_eff = state.kctl.k if fl.adaptive_k else k_static
                # compose at the REALISED cohort fraction — _topk_mask
                # selects ceil(k_eff) clients, not k_eff (ISSUE 4 bugfix)
                q_t = realized_cohort_fraction(k_eff, n_clients)
                z_t = sched_lib.scheduled_multiplier(sched, pr,
                                                     state.round_idx, rounds)
                sigma_t = z_t * pr.dp_clip
                acct_next = acct_lib.accountant_step(acct, z_t, q_t)
                eps_next = acct_lib.epsilon_from_state(acct_next, fl.dp_delta)
                live = (eps_next <= pr.dp_budget).astype(jnp.float32)
                state, m = round_step(state, batches,
                                      pr._replace(dp_sigma=sigma_t),
                                      update_gate=live)
                # spend the budget only for released rounds
                acct = jax.tree.map(lambda n, o: jnp.where(live > 0, n, o),
                                    acct_next, acct)
            else:
                state, m = round_step(state, batches, pr)
            cum_time = cum_time + simulate_round_time(fl, state.util,
                                                      m.sel_mask, m.failed,
                                                      params=pr, slow=m.slow)
            fail_mean = jnp.mean(m.failed)
            if scheduled:
                return ((state, data_key, cum_time, acct, sched),
                        (m.global_loss, m.k_effective, fail_mean, sigma_t,
                         live))
            return ((state, data_key, cum_time),
                    (m.global_loss, m.k_effective, fail_mean))

        def eval_block(carry, block_len):
            carry, ys = jax.lax.scan(one_round, carry, None,
                                     length=block_len)
            if scheduled:
                state, data_key, cum_time, acct, sched = carry
                losses, ks, fails, sigmas, lives = ys
            else:
                state, _, cum_time = carry
                losses, ks, fails = ys
            # metadata-only phase marker: tags the eval ops in profiler
            # traces / HLO names without touching the lowered math
            with jax.named_scope("eval_block"):
                acc = spec.accuracy(state.params, tx, ty)
                proba = spec.predict_proba(state.params, tx)[:, 1]
                auc = auc_roc_jnp(proba, ty)
            trace = {
                "loss": losses[-1],
                "acc": acc,
                "auc": auc,
                "k": ks[-1],
                "fail": fails[-1],
                "cum_time": cum_time,
            }
            if scheduled:
                trace["eps"] = acct_lib.epsilon_from_state(acct, fl.dp_delta)
                trace["sigma"] = sigmas[-1]
                trace["live"] = jnp.mean(lives)
                sched = sched_lib.scheduler_update(sched, auc, pr)
                carry = (state, data_key, cum_time, acct, sched)
            return carry, trace

        # param_axes sharding hook: identity outside a shardctx context
        # (every unsharded program lowers unchanged); under the context
        # run_fl_population installs for over-budget models, this seeds the
        # GSPMD layout of the whole round scan from the spec's declared
        # logical axes.
        params = spec.constrain_params(spec.init(jax.random.fold_in(key, 0)))
        state = rounds_lib.init_round_state(
            params, fl, jax.random.fold_in(key, 1), n_clients=n_clients,
            data_size=data_size, data_quality=data_quality,
        )
        carry = (state, jax.random.fold_in(key, 2), jnp.zeros((), jnp.float32))
        if scheduled:
            q_nom = jnp.asarray(min(fl.clients_per_round / n_clients, 1.0),
                                jnp.float32)
            carry = carry + (
                acct_lib.init_accountant_state(),
                sched_lib.init_scheduler(pr.dp_budget, fl.dp_delta, rounds,
                                         q_nom),
            )
        trace = None
        if n_full:
            carry, trace = jax.lax.scan(
                lambda c, _: eval_block(c, eval_every), carry, None,
                length=n_full)
        if rem:
            carry, tail = eval_block(carry, rem)
            tail = jax.tree.map(lambda x: x[None], tail)
            trace = tail if trace is None else jax.tree.map(
                lambda a, b: jnp.concatenate([a, b]), trace, tail)
        state, _, sim_time = carry[:3]
        return state.params, sim_time, trace

    return single_run


# Compiled runners keyed on (STATIC config, rounds, eval_every, DataMeta,
# n_lanes, stack shapes): the federation AND every scalar hyper-parameter
# (FLParams) are runtime arguments, so ONE program serves an entire
# ε/failure/lr grid — one compile per (method-statics, shapes) cell, not
# per grid point.  The STATIC config includes ``FLConfig.model``, so each
# detector architecture gets its own program and a model × seed grid
# compiles once per model.  RUNNER_STATS counts misses/hits so tests and
# benchmarks can assert the single-compile property.
_RUNNER_CACHE: Dict = {}
# A view of the unified registry (repro.obs.stats) — dict-style call sites
# (index, +=, dict(...)) work unchanged; STATS.snapshot()/reset()/expect()
# see it as the "runner" namespace.
RUNNER_STATS = obs_stats.STATS.counters("runner", misses=0, hits=0)

# Device-side federations cached per host FederatedData object, so repeat
# calls (seed loops, epsilon sweeps) skip the O(n_clients × max_n × d)
# re-pad + re-upload that stack_federation performs.  Keyed by id() with a
# weakref guard (FederatedData defines __eq__, so it is unhashable); dead
# entries are evicted by the weakref callback.
_STACK_CACHE: Dict[int, tuple] = {}


def _device_federation(fed: FederatedData):
    key = id(fed)
    entry = _STACK_CACHE.get(key)
    if entry is None or entry[0]() is not fed:
        sizes = fed.data_sizes()
        ref = weakref.ref(fed, lambda _: _STACK_CACHE.pop(key, None))
        entry = (ref, stack_federation(fed),
                 jnp.asarray(sizes / sizes.mean()),
                 jnp.asarray(fed.label_entropy()))
        _STACK_CACHE[key] = entry
    return entry[1], entry[2], entry[3]


def _get_runner(fl: FLConfig, rounds: int, eval_every: int, meta: DataMeta,
                n_lanes: int, stack: StackedFederation):
    """Compiled ``runner(keys[L], stack, data_size, data_quality,
    params_lanes[L]) -> (params[L], sim_time[L], trace[L])``.

    Keyed on the STATIC config (which includes ``model``) only: two configs
    that differ in runtime knobs (ε, failure prob, lrs, ...) resolve to the
    same cache entry and the same XLA program.  Off-CPU, the per-lane
    inputs (keys + FLParams) are donated — they are rebuilt per call, so
    XLA may alias them into the scan carry instead of holding both live.
    """
    static = fl_static(fl)
    cache_key = (static, rounds, eval_every, meta, n_lanes, stack.shapes())
    runner = _RUNNER_CACHE.get(cache_key)
    if runner is None:
        RUNNER_STATS["misses"] += 1
        obs_trace.event("compile.runner_miss", engine="sweep",
                        model=static.model, rounds=rounds,
                        n_lanes=n_lanes, cache_size=len(_RUNNER_CACHE))
        with obs_trace.span("runner.build", engine="sweep",
                            model=static.model):
            single_run = _build_single_run(static, rounds, eval_every, meta)
            donate = () if jax.default_backend() == "cpu" else (0, 4)
            runner = jax.jit(
                jax.vmap(single_run, in_axes=(0, None, None, None, 0)),
                donate_argnums=donate,
            )
        _RUNNER_CACHE[cache_key] = runner
    else:
        RUNNER_STATS["hits"] += 1
    return runner


def _lane_sharding(n_lanes: int):
    """(n_devices, lane_sharding, replicated_sharding) over a 1-D device
    mesh, or ``None`` on a single device.  The caller pads the lane axis up
    to a multiple of ``n_devices`` (duplicating trailing lanes, dropped on
    readback) so every device carries whole lanes — a 17-lane sweep on 16
    devices runs two waves instead of falling back to one device."""
    devices = jax.devices()
    n = min(len(devices), n_lanes)
    if n <= 1:
        return None
    mesh = jax.sharding.Mesh(np.asarray(devices[:n]), ("lane",))
    return (n, NamedSharding(mesh, PartitionSpec("lane")),
            NamedSharding(mesh, PartitionSpec()))


def _sweep_cells(fl: FLConfig, params_grid: Sequence, method: str,
                 capability: str = "driver_capable") -> List[FLConfig]:
    """Resolve a params_grid into per-cell FLConfigs sharing ``fl``'s
    statics (shared by the sweep and population engines).

    Each cell's plan is resolved against the core/plans registry and must
    carry ``capability`` (``driver_capable`` for the dense engines,
    ``cohort_capable`` for the population engine).  Plans in the same
    *family* (``fl_static`` canonicalises plan → family) may differ across
    cells — that is how a mixed sync/async/hierarchical frontier rides the
    ``plan_code`` lane of one compiled program.
    """
    cells: List[FLConfig] = []
    for p in params_grid:
        if isinstance(p, FLConfig):
            cell = fl_for_method(p, method)
        elif isinstance(p, FLParams):
            # plan_code is derived from FLConfig.plan, not a config field:
            # map a differing code back to the registered plan name
            overrides = p._asdict()
            code = float(overrides.pop("plan_code"))
            cell = dataclasses.replace(fl, **overrides)
            if code != plans_lib.plan_code(cell.plan):
                cell = dataclasses.replace(
                    cell, plan=plans_lib.plan_for_code(
                        plans_lib.plan_family(cell.plan), code))
        else:
            cell = dataclasses.replace(fl, **dict(p))
        if not getattr(plans_lib.get_plan(cell.plan), capability):
            raise ValueError(
                f"plan {cell.plan!r} cannot run on this engine: the "
                f"core/plans registry marks it {capability}=False")
        if fl_static(cell) != fl_static(fl):
            raise ValueError(
                "params_grid cell differs from the base config in a STATIC "
                "field — those gate code structure and cannot ride the "
                f"runtime lane axis: {cell}")
        cells.append(cell)
    return cells


def _params_lanes(cells: Sequence[FLConfig], n_seeds: int) -> FLParams:
    """Stack each cell's runtime params into [n_cells·n_seeds] f32 lanes
    (cell-major: lane = cell_index * n_seeds + seed_index)."""
    per_cell = [fl_params(c) for c in cells]
    return jax.tree.map(
        lambda *xs: jnp.repeat(jnp.asarray(xs, jnp.float32), n_seeds),
        *per_cell)


def run_fl_sweep(
    fed: FederatedData,
    fl: FLConfig,
    params_grid: Sequence,
    seeds: Sequence[int] = (0, 1, 2, 3),
    method: str = "proposed",
    rounds: Optional[int] = None,
    eval_every: int = 10,
    dataset: str = "unsw",
    hidden: int = 64,
    return_params: bool = False,
) -> List[List[RunResult]]:
    """An entire hyper-parameter sweep as ONE compiled program.

    ``params_grid``: one entry per sweep cell — an :class:`FLConfig` sharing
    ``fl``'s statics, a dict of runtime-field overrides applied to ``fl``
    (e.g. ``{"dp_epsilon": 0.1}``), or an :class:`FLParams`.  The engine
    stacks every cell's runtime scalars into a **seed×config lane axis**
    (``len(params_grid) · len(seeds)`` lanes), vmaps the scanned round loop
    over it, and shards the lane axis across the available devices
    (``NamedSharding`` over a 1-D ``lane`` mesh — on one device the program
    is identical, on N devices each carries ``lanes/N`` trials).

    One ``_get_runner`` miss covers the WHOLE grid (the cache keys on
    statics + shapes, not cell values): a Fig.-3 ε column or a Table-II
    failure sweep compiles once and then runs every cell·seed lane in a
    single device program.  Lane semantics match the per-cell engine —
    ``run_fl_sweep(..., [cfg_a, cfg_b], seeds)[i][j]`` equals
    ``run_fl(fed, cfg_i, seed=seeds[j])`` lane for lane (tested in
    tests/test_sweep.py).

    Returns results indexed ``[cell][seed]``.
    """
    fl = fl_for_method(fl, method)
    rounds = int(rounds or fl.rounds)
    seeds = [int(s) for s in seeds]
    cells = _sweep_cells(fl, params_grid, method)
    if not cells:
        return []

    n_lanes = len(cells) * len(seeds)
    sharding = _lane_sharding(n_lanes)
    n_padded = n_lanes
    if sharding is not None:
        n_padded = -(-n_lanes // sharding[0]) * sharding[0]

    t0 = time.time()
    with obs_trace.span("sweep.prepare", method=method, n_lanes=n_lanes,
                        n_cells=len(cells), rounds=rounds,
                        plans=",".join(sorted({c.plan for c in cells}))):
        meta = meta_for(fed, hidden=hidden)
        stack, data_size, data_quality = _device_federation(fed)
        runner = _get_runner(fl, rounds, eval_every, meta, n_padded, stack)
        keys = jax.vmap(jax.random.key)(
            jnp.asarray(np.tile(seeds, len(cells)), jnp.uint32))
        lanes = _params_lanes(cells, len(seeds))
        if n_padded > n_lanes:
            pad = n_padded - n_lanes
            keys = jnp.concatenate([keys, jnp.repeat(keys[-1:], pad, axis=0)])
            lanes = jax.tree.map(
                lambda x: jnp.concatenate(
                    [x, jnp.repeat(x[-1:], pad, axis=0)]),
                lanes)

        if sharding is not None:
            _, s_lane, s_rep = sharding
            keys = jax.device_put(keys, s_lane)
            lanes = jax.tree.map(lambda x: jax.device_put(x, s_lane), lanes)
            stack, data_size, data_quality = jax.tree.map(
                lambda x: jax.device_put(x, s_rep),
                (stack, data_size, data_quality))

    with obs_trace.span("sweep.execute", n_lanes=n_lanes):
        params_b, sim_b, trace_b = runner(keys, stack, data_size,
                                          data_quality, lanes)
        jax.block_until_ready(sim_b)
    wall_per_lane = (time.time() - t0) / max(n_lanes, 1)

    eval_idx = _eval_rounds(rounds, eval_every)
    with obs_trace.span("sweep.readback", n_lanes=n_lanes):
        trace_np = {k: np.asarray(v) for k, v in trace_b.items()}
        sim_np = np.asarray(sim_b)
    # one spec for every lane (model is static) — rebuilding per lane would
    # defeat _personalize's jit cache for closure-built specs
    spec = get_model_spec(fl.model, meta) if method == "fedl2p" else None
    out: List[List[RunResult]] = []
    for ci, cell in enumerate(cells):
        # fixed-σ cells: host closed-form composition (engine-independent);
        # scheduled cells: ε comes from the lane's in-scan accountant trace
        scheduled = cell.dp_enabled and cell.dp_scheduled
        eps_cell = None if scheduled else accounted_epsilon(cell, rounds)
        row = []
        for si, seed in enumerate(seeds):
            lane = ci * len(seeds) + si
            history = {"round": [r + 1 for r in eval_idx]}
            for name in trace_np:
                history[name] = [float(x) for x in trace_np[name][lane]]
            eps = history["eps"][-1] if scheduled else eps_cell
            sim_time = float(sim_np[lane])
            acc, auc = history["acc"][-1], history["auc"][-1]
            if method == "fedl2p":
                # personalisation pass (the point of FedL2P) + simulated cost
                acc, auc = _personalize(
                    jax.tree.map(lambda x: x[lane], params_b), fed, spec,
                    seed=seed)
                sim_time *= 1.2
            lane_params = None
            if return_params:
                lane_params = jax.tree.map(lambda x: np.asarray(x[lane]),
                                           params_b)
            row.append(RunResult(
                method=method, dataset=dataset, seed=seed,
                accuracy=acc, auc=auc,
                sim_time_s=sim_time, wall_time_s=wall_per_lane,
                rounds=rounds, eps_spent=eps, history=history,
                params=lane_params,
            ))
        out.append(row)
    return out


def run_fl_batch(
    fed: FederatedData,
    fl: FLConfig,
    method: str = "proposed",
    seeds: Sequence[int] = (0, 1, 2, 3),
    rounds: Optional[int] = None,
    eval_every: int = 10,
    dataset: str = "unsw",
    hidden: int = 64,
    return_params: bool = False,
) -> List[RunResult]:
    """All repeated trials of one (method, dataset) cell as ONE compiled
    program: a single-cell :func:`run_fl_sweep` (vmap over the seed lanes).

    Per-seed results are bit-for-bit the batched lanes of the single-seed
    scanned engine (each lane keys off ``jax.random.key(seed)``), so
    ``run_fl_batch(seeds=[a, b])`` ≈ ``[run_fl(seed=a), run_fl(seed=b)]``
    at a fraction of the dispatch cost.  ``wall_time_s`` on each result is
    the batch wall time amortised over the seeds.
    """
    return run_fl_sweep(fed, fl, [fl], seeds=seeds, method=method,
                        rounds=rounds, eval_every=eval_every, dataset=dataset,
                        hidden=hidden, return_params=return_params)[0]


def run_fl(
    fed: FederatedData,
    fl: FLConfig,
    method: str = "proposed",
    seed: int = 0,
    rounds: Optional[int] = None,
    eval_every: int = 10,
    dataset: str = "unsw",
    hidden: int = 64,
    return_params: bool = False,
) -> RunResult:
    """Single-seed front door of the compiled engine (a batch of one)."""
    return run_fl_batch(fed, fl, method, seeds=(seed,), rounds=rounds,
                        eval_every=eval_every, dataset=dataset,
                        hidden=hidden, return_params=return_params)[0]


# ---------------------------------------------------------------------------
# Population engine (ISSUE 6): cohort training over a sharded client axis
# ---------------------------------------------------------------------------


def _build_population_run(fl: FLConfig, rounds: int, eval_every: int,
                          meta: DataMeta, sel_chunks: int):
    """``single_run(key, pop, params) -> (final_params, sim_time, trace)``
    over a :class:`~repro.data.synthetic.Population` — the population-scale
    sibling of :func:`_build_single_run` (ARCHITECTURE.md §Scale).

    Same nested-scan structure, same scheduled-privacy carry, but the round
    step is the ``client_cohort`` plan
    (:func:`repro.core.rounds.make_cohort_round`): per-round COMPUTE is
    O(k_max) — only the top-k cohort's data/state is gathered to the
    compute lanes — while O(N) work is limited to elementwise vector ops
    that shard over the ``client`` mesh axis.  Every per-round emission is
    a SCALAR (loss, k, population failure fraction, σ, live): at 10^5+
    clients an [N]-shaped ys column would dominate memory, so the per-round
    trace never materialises the population axis.

    The cohort time model waits for the slowest *selected* client:
    :func:`simulate_round_time` reads compute capacities through a
    cohort-gathered view of the utility state, with the cohort-shaped
    ``take``/``failed``/``slow`` columns from :class:`CohortMetrics`.
    """
    n_full, rem = divmod(rounds, eval_every)
    scheduled = fl.dp_enabled and fl.dp_scheduled
    if scheduled and fl.dp_mode != "clipped":
        raise ValueError(
            "dp_scheduled requires dp_mode='clipped': the accountant "
            "composes z_t = sigma_t/dp_clip, which is only a valid "
            "(epsilon, delta) statement when updates are clipped to dp_clip")
    spec = get_model_spec(fl.model, meta)
    k_cap = float(int(fl.k_max))

    def single_run(key, pop: Population, pr: FLParams):
        n_clients = pop.n_clients

        def sample_fn(k, p, idx):
            return sample_cohort_batches(k, p, idx, fl.local_epochs,
                                         fl.local_batch)

        round_step = rounds_lib.make_cohort_round(
            spec.loss, fl, n_clients, sample_fn, sel_chunks=sel_chunks)
        tx, ty = pop.test_x, pop.test_y
        k_static = jnp.asarray(float(fl.clients_per_round), jnp.float32)

        def one_round(carry, _):
            if scheduled:
                state, data_key, cum_time, acct, sched = carry
            else:
                state, data_key, cum_time = carry
            data_key, k_batch = jax.random.split(data_key)
            if scheduled:
                k_eff = state.kctl.k if fl.adaptive_k else k_static
                # the cohort plan caps the controller at the static cohort
                # size, so the accountant must see the same realised k
                k_eff = jnp.minimum(k_eff, k_cap)
                q_t = realized_cohort_fraction(k_eff, n_clients)
                z_t = sched_lib.scheduled_multiplier(sched, pr,
                                                     state.round_idx, rounds)
                sigma_t = z_t * pr.dp_clip
                acct_next = acct_lib.accountant_step(acct, z_t, q_t)
                eps_next = acct_lib.epsilon_from_state(acct_next, fl.dp_delta)
                live = (eps_next <= pr.dp_budget).astype(jnp.float32)
                state, m = round_step(state, pop, k_batch,
                                      pr._replace(dp_sigma=sigma_t),
                                      update_gate=live)
                acct = jax.tree.map(lambda a, o: jnp.where(live > 0, a, o),
                                    acct_next, acct)
            else:
                state, m = round_step(state, pop, k_batch, pr)
            util_view = state.util._replace(
                compute=state.util.compute[m.cohort_idx])
            cum_time = cum_time + simulate_round_time(
                fl, util_view, m.take, m.failed, params=pr, slow=m.slow)
            if scheduled:
                return ((state, data_key, cum_time, acct, sched),
                        (m.global_loss, m.k_effective, m.fail_frac, sigma_t,
                         live))
            return ((state, data_key, cum_time),
                    (m.global_loss, m.k_effective, m.fail_frac))

        def eval_block(carry, block_len):
            carry, ys = jax.lax.scan(one_round, carry, None, length=block_len)
            if scheduled:
                state, data_key, cum_time, acct, sched = carry
                losses, ks, fails, sigmas, lives = ys
            else:
                state, _, cum_time = carry
                losses, ks, fails = ys
            # metadata-only phase marker: tags the eval ops in profiler
            # traces / HLO names without touching the lowered math
            with jax.named_scope("eval_block"):
                acc = spec.accuracy(state.params, tx, ty)
                proba = spec.predict_proba(state.params, tx)[:, 1]
                auc = auc_roc_jnp(proba, ty)
            trace = {
                "loss": losses[-1],
                "acc": acc,
                "auc": auc,
                "k": ks[-1],
                "fail": fails[-1],
                "cum_time": cum_time,
            }
            if scheduled:
                trace["eps"] = acct_lib.epsilon_from_state(acct, fl.dp_delta)
                trace["sigma"] = sigmas[-1]
                trace["live"] = jnp.mean(lives)
                sched = sched_lib.scheduler_update(sched, auc, pr)
                carry = (state, data_key, cum_time, acct, sched)
            return carry, trace

        # param_axes sharding hook (see _build_single_run): a no-op unless
        # run_fl_population traced this program under a shardctx context
        params = spec.constrain_params(spec.init(jax.random.fold_in(key, 0)))
        state = rounds_lib.init_round_state(
            params, fl, jax.random.fold_in(key, 1), n_clients=n_clients,
            data_size=pop.data_size, data_quality=pop.data_quality,
        )
        carry = (state, jax.random.fold_in(key, 2), jnp.zeros((), jnp.float32))
        if scheduled:
            q_nom = jnp.asarray(
                min(min(fl.clients_per_round, int(fl.k_max)) / n_clients, 1.0),
                jnp.float32)
            carry = carry + (
                acct_lib.init_accountant_state(),
                sched_lib.init_scheduler(pr.dp_budget, fl.dp_delta, rounds,
                                         q_nom),
            )
        trace = None
        if n_full:
            carry, trace = jax.lax.scan(
                lambda c, _: eval_block(c, eval_every), carry, None,
                length=n_full)
        if rem:
            carry, tail = eval_block(carry, rem)
            tail = jax.tree.map(lambda x: x[None], tail)
            trace = tail if trace is None else jax.tree.map(
                lambda a, b: jnp.concatenate([a, b]), trace, tail)
        state, _, sim_time = carry[:3]
        return state.params, sim_time, trace

    return single_run


def _get_population_runner(fl: FLConfig, rounds: int, eval_every: int,
                           meta: DataMeta, n_lanes: int, pop: Population,
                           sel_chunks: int, model_shard_key=None):
    """Compiled ``runner(keys[L], pop, params_lanes[L])`` for the population
    engine.  Shares ``_RUNNER_CACHE``/``RUNNER_STATS`` with the dense sweep
    engine (a "pop" tag keeps the key spaces disjoint), so the
    single-compile property is asserted the same way: one miss per
    (statics, rounds, cadence, shapes, chunk policy), hits thereafter.
    ``sel_chunks`` is part of the key — it changes the lowered selection
    loop (bitwise-neutral, but a different program).  ``model_shard_key``
    (the mesh layout when the param_axes hook is armed, else None) is part
    of the key too: the sharded-model trace is a different program."""
    static = fl_static(fl)
    cache_key = ("pop", static, rounds, eval_every, meta, n_lanes,
                 pop.shapes(), int(sel_chunks), model_shard_key)
    runner = _RUNNER_CACHE.get(cache_key)
    if runner is None:
        RUNNER_STATS["misses"] += 1
        obs_trace.event("compile.runner_miss", engine="population",
                        model=static.model, rounds=rounds,
                        n_lanes=n_lanes, cache_size=len(_RUNNER_CACHE))
        with obs_trace.span("runner.build", engine="population",
                            model=static.model):
            single_run = _build_population_run(static, rounds, eval_every,
                                               meta, int(sel_chunks))
            donate = () if jax.default_backend() == "cpu" else (0, 2)
            runner = jax.jit(
                jax.vmap(single_run, in_axes=(0, None, 0)),
                donate_argnums=donate,
            )
        _RUNNER_CACHE[cache_key] = runner
    else:
        RUNNER_STATS["hits"] += 1
    return runner


def run_fl_population(
    pop: Population,
    fl: FLConfig,
    params_grid: Optional[Sequence] = None,
    seeds: Sequence[int] = (0,),
    method: str = "proposed",
    rounds: Optional[int] = None,
    eval_every: int = 10,
    dataset: str = "unsw",
    hidden: int = 64,
    mesh_shape: Optional[tuple] = None,
    shard: bool = True,
    sel_chunks: Optional[int] = None,
    memory_budget_bytes: Optional[int] = None,
    model_replicated_max_bytes: Optional[int] = None,
) -> List[List[RunResult]]:
    """Population-scale front door: a hyper-parameter sweep over a
    100k+-client :class:`Population` as ONE compiled program.

    The lane semantics mirror :func:`run_fl_sweep` (cells × seeds lanes,
    results ``[cell][seed]``); the execution differs in three ways
    (ARCHITECTURE.md §Scale):

    * **client_cohort plan** — each round samples the cohort ON DEVICE
      (top-``ceil(k_eff)`` over the sharded utility scores) and gathers
      only those ``fl.k_max`` clients' membership rows and state to the
      compute lanes, so per-round compute and data traffic are O(k_max),
      independent of N (the sublinear-wall gate in
      benchmarks/bench_scale.py).
    * **2-D lane × client mesh** — :func:`repro.launch.mesh.make_scale_mesh`
      factorises the devices into (lane, client); lanes shard as in the
      sweep engine, and every per-client [N] array — the Population's
      membership table and the UtilityState/FaultState scan carries —
      shards over ``client`` (``models/sharding.py``).  ``mesh_shape``
      pins a layout; ``shard=False`` keeps everything replicated (the
      single-device program, used as the bitwise reference in
      tests/test_scale.py).
    * **auto-chunking policy** — when ``memory_budget_bytes`` is given,
      ``core/scale.auto_chunks`` sizes the selection chunk count so the
      [N]-shaped selection transients fit the per-device budget left
      after the resident population state + per-lane model replicas
      (DESIGN.md §7).  Chunked and unchunked selection are bitwise
      identical.
    * **model-sharding hook** — a detector whose ``param_bytes()``
      exceeds ``model_replicated_max_bytes`` (default
      ``core/scale.MODEL_REPLICATED_MAX_BYTES``) and declares
      ``ModelSpec.param_axes`` is traced under
      ``shardctx.sharding_ctx(RULES_MODEL_SCALE, mesh)``: its wide
      parameter axes tensor-parallel over the ``client`` mesh axis
      instead of replicating per lane.  History columns match the
      replicated run up to GSPMD reduction order
      (tests/test_models.py pins this on a 4-device mesh).

    ``fedl2p`` is rejected: its per-client personalisation pass is O(N)
    host work, which is exactly what this engine exists to avoid.
    """
    if method == "fedl2p":
        raise ValueError(
            "run_fl_population does not support fedl2p: its host-side "
            "personalisation fine-tunes every client (O(N) python loop) — "
            "use the dense engine at dense-federation scale")
    fl = fl_for_method(fl, method)
    if not fl.k_max or int(fl.k_max) <= 0:
        raise ValueError(
            "run_fl_population needs an explicit positive FLConfig.k_max "
            "(the static cohort size gathered per round)")
    rounds = int(rounds or fl.rounds)
    seeds = [int(s) for s in seeds]
    cells = _sweep_cells(fl, [fl] if params_grid is None else params_grid,
                         method, capability="cohort_capable")
    if not cells:
        return []
    n_lanes = len(cells) * len(seeds)

    meta = meta_for(pop, hidden=hidden)
    spec = get_model_spec(fl.model, meta)
    model_bytes = spec.param_bytes()

    if sel_chunks is None:
        sel_chunks = 1 if memory_budget_bytes is None else scale_lib.auto_chunks(
            pop.n_clients, int(memory_budget_bytes),
            pop.members_per_client, n_lanes, model_bytes=model_bytes)

    mesh = make_scale_mesh(n_lanes, shape=mesh_shape) if shard else None
    n_padded = n_lanes
    if mesh is not None:
        lane_size = mesh.shape["lane"]
        n_padded = -(-n_lanes // lane_size) * lane_size

    # ModelSpec sharding hook: when the detector's replicated parameter
    # footprint exceeds the budget (core/scale.py) AND the spec declares
    # param_axes, trace the runner under the RULES_MODEL_SCALE context so
    # the spec's constrain_params calls tensor-parallel the model over the
    # mesh's client axis.  The decision is part of the runner-cache key —
    # sharded and replicated traces are different programs.
    model_ctx = contextlib.nullcontext()
    model_shard_key = None
    if (mesh is not None and mesh.shape["client"] > 1
            and spec.param_axes is not None
            and scale_lib.model_needs_sharding(model_bytes,
                                               model_replicated_max_bytes)):
        model_ctx = shardctx.sharding_ctx(shard_lib.RULES_MODEL_SCALE, mesh)
        model_shard_key = tuple(sorted(mesh.shape.items()))

    t0 = time.time()
    runner = _get_population_runner(fl, rounds, eval_every, meta, n_padded,
                                    pop, sel_chunks,
                                    model_shard_key=model_shard_key)
    keys = jax.vmap(jax.random.key)(
        jnp.asarray(np.tile(seeds, len(cells)), jnp.uint32))
    lanes = _params_lanes(cells, len(seeds))
    if n_padded > n_lanes:
        pad = n_padded - n_lanes
        keys = jnp.concatenate([keys, jnp.repeat(keys[-1:], pad, axis=0)])
        lanes = jax.tree.map(
            lambda x: jnp.concatenate([x, jnp.repeat(x[-1:], pad, axis=0)]),
            lanes)

    if mesh is not None:
        s_lane, _ = shard_lib.lane_shardings(mesh)
        keys = jax.device_put(keys, s_lane)
        lanes = jax.tree.map(lambda x: jax.device_put(x, s_lane), lanes)
        if pop.n_clients % mesh.shape["client"] == 0:
            pop = jax.device_put(pop, shard_lib.population_shardings(mesh, pop))
        else:
            # uneven client axis: replicate rather than shard (correct but
            # unscaled — pad the population to a device multiple to shard)
            rep = NamedSharding(mesh, PartitionSpec())
            pop = jax.device_put(pop, jax.tree.map(lambda _: rep, pop))

    with model_ctx:
        params_b, sim_b, trace_b = runner(keys, pop, lanes)
    jax.block_until_ready(sim_b)
    wall_per_lane = (time.time() - t0) / max(n_lanes, 1)

    eval_idx = _eval_rounds(rounds, eval_every)
    trace_np = {k: np.asarray(v) for k, v in trace_b.items()}
    sim_np = np.asarray(sim_b)
    out: List[List[RunResult]] = []
    for ci, cell in enumerate(cells):
        scheduled = cell.dp_enabled and cell.dp_scheduled
        eps_cell = None if scheduled else accounted_epsilon(cell, rounds)
        row = []
        for si, seed in enumerate(seeds):
            lane = ci * len(seeds) + si
            history = {"round": [r + 1 for r in eval_idx]}
            for name in trace_np:
                history[name] = [float(x) for x in trace_np[name][lane]]
            row.append(RunResult(
                method=method, dataset=dataset, seed=seed,
                accuracy=history["acc"][-1], auc=history["auc"][-1],
                sim_time_s=float(sim_np[lane]), wall_time_s=wall_per_lane,
                rounds=rounds,
                eps_spent=history["eps"][-1] if scheduled else eps_cell,
                history=history,
            ))
        out.append(row)
    return out


# ---------------------------------------------------------------------------
# Legacy engine: per-round Python loop (semantic oracle for the scan engine)
# ---------------------------------------------------------------------------


def run_fl_legacy(
    fed: FederatedData,
    fl: FLConfig,
    method: str = "proposed",
    seed: int = 0,
    rounds: Optional[int] = None,
    eval_every: int = 10,
    dataset: str = "unsw",
    hidden: int = 64,
) -> RunResult:
    """The original dispatch-per-round driver.  Kept (not deprecated) as the
    reference semantics: host-side NumPy batch sampling, one jit'd round
    step per iteration, eval pulled to host at every ``eval_every``.

    Scheduled-budget accounting (``dp_scheduled``) is a compiled-engine
    feature — the accountant/scheduler state rides the scan carry — so this
    loop rejects such configs instead of silently ignoring the budget."""
    fl = fl_for_method(fl, method)
    if fl.dp_enabled and fl.dp_scheduled:
        raise ValueError(
            "run_fl_legacy does not support dp_scheduled configs; use the "
            "compiled engine (run_fl / run_fl_batch / run_fl_sweep)")
    legacy_plan = plans_lib.get_plan(fl.plan)
    if legacy_plan.family != "client_parallel" or legacy_plan.code != 0.0:
        raise ValueError(
            f"run_fl_legacy implements only the synchronous client_parallel "
            f"plan; plan {fl.plan!r} needs the compiled engine "
            "(run_fl / run_fl_sweep)")
    rounds = rounds or fl.rounds
    rng = np.random.default_rng(seed)
    key = jax.random.key(seed)

    spec = get_model_spec(fl.model, meta_for(fed, hidden=hidden))
    params = spec.init(jax.random.fold_in(key, 0))
    sizes = fed.data_sizes()
    state = rounds_lib.init_round_state(
        params, fl, jax.random.fold_in(key, 1), n_clients=fed.n_clients,
        data_size=jnp.asarray(sizes / sizes.mean()),
        data_quality=jnp.asarray(fed.label_entropy()),
    )
    round_step = jax.jit(
        rounds_lib.make_parallel_round(spec.loss, fl, fed.n_clients)
    )

    tx, ty = jnp.asarray(fed.test_x), jnp.asarray(fed.test_y)
    history = {"round": [], "loss": [], "acc": [], "auc": [], "k": [],
               "fail": [], "cum_time": []}
    sim_time = 0.0
    t0 = time.time()
    for r in range(rounds):
        batches = jax.tree.map(
            jnp.asarray, round_batches(rng, fed, fl.local_epochs, fl.local_batch)
        )
        state, metrics = round_step(state, batches)
        sim_time += float(simulate_round_time(fl, state.util, metrics.sel_mask,
                                              metrics.failed,
                                              slow=metrics.slow))
        if (r + 1) % eval_every == 0 or r == rounds - 1:
            acc = float(spec.accuracy(state.params, tx, ty))
            proba = np.asarray(spec.predict_proba(state.params, tx)[:, 1])
            auc = auc_roc(proba, fed.test_y)
            history["round"].append(r + 1)
            history["loss"].append(float(metrics.global_loss))
            history["acc"].append(acc)
            history["auc"].append(auc)
            history["k"].append(float(metrics.k_effective))
            history["fail"].append(float(jnp.mean(metrics.failed)))
            history["cum_time"].append(sim_time)

    acc, auc = history["acc"][-1], history["auc"][-1]
    if method == "fedl2p":
        # personalisation pass (the point of FedL2P) + its simulated cost
        acc, auc = _personalize(state.params, fed, spec, seed=seed)
        sim_time *= 1.2
    eps = accounted_epsilon(fl, rounds)

    return RunResult(
        method=method, dataset=dataset, seed=seed,
        accuracy=acc, auc=auc,
        sim_time_s=sim_time, wall_time_s=time.time() - t0,
        rounds=rounds, eps_spent=eps, history=history,
    )
