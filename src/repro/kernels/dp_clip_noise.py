"""Fused DP clip+noise as Pallas TPU kernels (the paper's hot DP step).

The (ε, δ) mechanism touches every byte of every client update — for a 123B
model that is ~0.5 TB of HBM traffic per round.  Fusing the clip-scale and
noise-add into one tiled pass bounds traffic at 2 reads + 1 write per
element; the global-norm reduction is a separate single-read pass (needed
before any scaling can happen).

Two kernels:
  * ``sumsq``      — tiled Σx² reduction (SMEM scalar accumulated across the
                     sequential TPU grid).
  * ``scale_noise``— o = x·scale + σ·n elementwise over [bt, 128] VMEM tiles.

NOTE: validation runs in ``interpret=True`` on CPU where ``pltpu.prng_*`` has
no lowering, so standard-normal noise is an explicit operand here.  On real
TPU the noise read can be removed by seeding ``pltpu.prng_seed`` per tile and
box-mullering ``prng_random_bits`` in-register — same contract, one fewer
operand; see EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128


def _sumsq_kernel(x_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[0, 0] = jnp.zeros((), jnp.float32)

    x = x_ref[...].astype(jnp.float32)
    o_ref[0, 0] += jnp.sum(x * x)


def _scale_noise_kernel(scale_ref, x_ref, n_ref, o_ref, *, sigma: float):
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = (x * scale_ref[0] + sigma * n_ref[...].astype(jnp.float32)).astype(
        o_ref.dtype
    )


def _pad_2d(x, bt: int):
    n = x.size
    rows = -(-n // LANES)
    rows_pad = -(-rows // bt) * bt
    flat = jnp.zeros((rows_pad * LANES,), x.dtype).at[:n].set(x.reshape(-1))
    return flat.reshape(rows_pad, LANES), rows_pad


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def sumsq(x, *, bt: int = 256, interpret: bool = True) -> jnp.ndarray:
    """Σ x² over a flat array, tiled [bt, 128] (zero-padded)."""
    x2d, rows = _pad_2d(x, bt)
    out = pl.pallas_call(
        _sumsq_kernel,
        grid=(rows // bt,),
        in_specs=[pl.BlockSpec((bt, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec(memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(x2d)
    return out[0, 0]


@functools.partial(jax.jit, static_argnames=("sigma", "bt", "interpret"))
def scale_noise(x, noise, scale, *, sigma: float, bt: int = 256,
                interpret: bool = True):
    """o = x·scale + σ·noise (elementwise, shape preserved)."""
    shape, n = x.shape, x.size
    x2d, rows = _pad_2d(x, bt)
    n2d, _ = _pad_2d(noise, bt)
    scale_arr = jnp.asarray(scale, jnp.float32).reshape(1)
    out = pl.pallas_call(
        functools.partial(_scale_noise_kernel, sigma=float(sigma)),
        grid=(rows // bt,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((bt, LANES), lambda i: (i, 0)),
            pl.BlockSpec((bt, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bt, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), x.dtype),
        interpret=interpret,
    )(scale_arr, x2d, n2d)
    return out.reshape(-1)[:n].reshape(shape)


def dp_clip_noise(x, noise, clip: float, sigma: float, *, bt: int = 256,
                  interpret: bool = True):
    """Full fused mechanism on one flat array: clip to L2 ``clip``, add
    σ-scaled standard-normal ``noise``.  Matches ``ref.dp_clip_noise_ref``."""
    norm = jnp.sqrt(sumsq(x, bt=bt, interpret=interpret))
    scale = jnp.minimum(1.0, clip / jnp.maximum(norm, 1e-12))
    return scale_noise(x, noise, scale, sigma=sigma, bt=bt, interpret=interpret), norm
