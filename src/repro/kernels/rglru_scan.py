"""RG-LRU linear recurrence h_t = a_t·h_{t-1} + x_t as a Pallas TPU kernel.

Grid: (batch, W/bw, L/chunk) with the *sequence-chunk axis innermost* so the
hidden state persists in VMEM scratch across chunk steps (TPU grids execute
sequentially).  Inside a chunk the recurrence is a `fori_loop` over time —
elementwise VPU work on [1, bw] rows; HBM traffic is exactly one read of
(a, x) and one write of h, which is the bandwidth floor for this op.

XLA's alternative (`associative_scan`) does O(log L) full passes over the
sequence; this kernel is the paper-agnostic beyond-XLA win for the
RecurrentGemma architecture (EXPERIMENTS.md §Perf discusses the trade-off).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(h0_ref, a_ref, x_ref, h_ref, hlast_ref, state_scr, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)  # [chunk, bw]
    x = x_ref[0].astype(jnp.float32)

    def step(t, carry):
        h = carry
        h = a[t] * h + x[t]
        h_ref[0, t] = h.astype(h_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, state_scr[0])
    state_scr[...] = h[None]

    @pl.when(ci == nc - 1)
    def _finish():
        hlast_ref[0] = h.astype(hlast_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "bw", "interpret"))
def rglru_scan(a, x, h0=None, *, chunk: int = 128, bw: int = 512,
               interpret: bool = True):
    """a, x: [B, L, W]; h0: [B, W] or None -> (h [B,L,W], h_last [B,W])."""
    b, l, w = a.shape
    chunk = min(chunk, l)
    bw = min(bw, w)
    assert l % chunk == 0 and w % bw == 0, (l, chunk, w, bw)
    if h0 is None:
        h0 = jnp.zeros((b, w), jnp.float32)

    grid = (b, w // bw, l // chunk)
    h, hlast = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bw), lambda bi, wi, ci: (bi, wi)),
            pl.BlockSpec((1, chunk, bw), lambda bi, wi, ci: (bi, ci, wi)),
            pl.BlockSpec((1, chunk, bw), lambda bi, wi, ci: (bi, ci, wi)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, bw), lambda bi, wi, ci: (bi, ci, wi)),
            pl.BlockSpec((1, bw), lambda bi, wi, ci: (bi, wi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, l, w), jnp.float32),
            jax.ShapeDtypeStruct((b, w), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        interpret=interpret,
    )(h0, a, x)
    return h, hlast
