"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None) -> jnp.ndarray:
    """q: [B,S,HQ,D]; k,v: [B,T,HKV,D] -> [B,S,HQ,D] (f32 math)."""
    b, s, hq, d = q.shape
    t = k.shape[1]
    hkv = k.shape[2]
    g = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, s, hkv, g, d)
    scores = jnp.einsum("bskgd,btkd->bkgst", qf, k.astype(jnp.float32)) / math.sqrt(d)
    i = jnp.arange(s)[:, None]
    j = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= j <= i + (t - s)
    if window is not None:
        mask &= j > i + (t - s) - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, hq, d).astype(q.dtype)


def flash_decode_ref(q, k, v, length) -> jnp.ndarray:
    """One-token decode.  q: [B,HQ,D]; k,v: [B,T,HKV,D]; length: [] or [B]
    number of valid cache positions.  Returns [B,HQ,D]."""
    b, hq, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, d)
    scores = jnp.einsum("bkgd,btkd->bkgt", qf, k.astype(jnp.float32)) / math.sqrt(d)
    valid = jnp.arange(t)[None] < jnp.broadcast_to(jnp.asarray(length), (b,))[:, None]
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, hq, d).astype(q.dtype)


def dp_clip_noise_ref(x, noise_unit, clip: float, sigma: float,
                      norm: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Fused clip-to-norm + add sigma-scaled noise oracle.

    x: [N] f32 flat update; noise_unit: [N] standard normal; clip: L2 bound.
    """
    if norm is None:
        norm = jnp.sqrt(jnp.sum(jnp.square(x.astype(jnp.float32))))
    scale = jnp.minimum(1.0, clip / jnp.maximum(norm, 1e-12))
    return (x.astype(jnp.float32) * scale + sigma * noise_unit).astype(x.dtype)


def dp_clip_noise_tree_ref(tree, key, clip: float, sigma: float):
    """Pure-jnp tree fallback with the SAME contract as
    ``kernels.ops.dp_clip_noise_tree``: shared global norm across leaves,
    one noise key per leaf (split order = leaf order).  This is the CPU
    fallback the FL aggregation path uses when no TPU is attached.

    Returns (noised_tree, pre_clip_global_norm).
    """
    leaves, treedef = jax.tree.flatten(tree)
    norm = jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )
    scale = jnp.minimum(1.0, clip / jnp.maximum(norm, 1e-12))
    keys = jax.random.split(key, len(leaves))
    out = [
        (l.astype(jnp.float32) * scale
         + sigma * jax.random.normal(k, l.shape, jnp.float32)).astype(l.dtype)
        for l, k in zip(leaves, keys)
    ]
    return jax.tree.unflatten(treedef, out), norm


def rglru_scan_ref(a, x, h0=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential-oracle linear recurrence h_t = a_t·h_{t-1} + x_t.

    a, x: [B,L,W] f32; h0: [B,W] or None.  Returns (h [B,L,W], h_last)."""
    b, l, w = a.shape
    h0 = jnp.zeros((b, w), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        at, xt = inp
        h = at * h + xt
        return h, h

    _, hs = jax.lax.scan(step, h0, (a.transpose(1, 0, 2), x.transpose(1, 0, 2)))
    hs = hs.transpose(1, 0, 2)
    return hs, hs[:, -1]
