"""Jit'd public wrappers over the Pallas kernels (stable API for the model
zoo and the FL core).  Each function dispatches to the kernel and is
validated against ``repro.kernels.ref`` in tests/test_kernels.py."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import dp_clip_noise as _dp
from repro.kernels import flash_attention as _fa
from repro.kernels import flash_decode as _fd
from repro.kernels import ref as _ref
from repro.kernels import rglru_scan as _rg


def pallas_backend_ready() -> bool:
    """True when the default backend can compile+run the Pallas TPU kernels.

    The FL aggregation path keys its DP routing off this: the fused
    clip+noise kernel on TPU, the ``kernels.ref`` jnp fallback elsewhere
    (interpret-mode Pallas is for validation, not production CPU runs).
    """
    return jax.default_backend() == "tpu"


def default_route() -> str:
    """Score-path kernel route for the serving engine (repro/serve): the
    Pallas kernels (``"kernel"``) when the backend can compile them, the
    pure-jnp ``kernels.ref`` fallback (``"ref"``) elsewhere — the same
    by-backend dispatch the DP clip+noise aggregation path uses."""
    return "kernel" if pallas_backend_ready() else "ref"


def flash_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                    interpret: Optional[bool] = None):
    """``interpret=None`` (default) auto-routes by backend: compiled Pallas
    on TPU, interpret mode elsewhere (``flash_decode.resolve_interpret``)."""
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               interpret=interpret)


def flash_decode(q, k, v, length, *, interpret: Optional[bool] = None,
                 return_partials: bool = False):
    """``interpret=None`` (default) auto-routes by backend — the kernel is
    never silently interpreted on real hardware."""
    return _fd.flash_decode(q, k, v, length, interpret=interpret,
                            return_partials=return_partials)


combine_decode_partials = _fd.combine_partials


def rglru_scan(a, x, h0=None, *, interpret: Optional[bool] = None):
    """``interpret=None`` (default) auto-routes by backend: compiled Pallas
    on TPU, interpret mode elsewhere — same resolve as the flash kernels.
    The sequential chunked scan is bitwise-equal to
    ``kernels.ref.rglru_scan_ref`` (asserted in tests/test_kernels.py), so
    the ``"kernel"``/``"ref"`` score routes of the sequence detectors agree
    to the bit."""
    return _rg.rglru_scan(a, x, h0,
                          interpret=_fd.resolve_interpret(interpret))


def dp_clip_noise(x, noise, clip: float, sigma: float, *, interpret: bool = True):
    return _dp.dp_clip_noise(x, noise, clip, sigma, interpret=interpret)


def dp_clip_noise_tree(tree, key, clip: float, sigma: float, *,
                       interpret: Optional[bool] = None):
    """Pytree version with a SHARED global norm (client-level DP contract —
    identical semantics to core.dp.privatize_update(mode='clipped')).

    ``interpret=None`` auto-routes: compiled Pallas when the backend is TPU,
    the ``kernels.ref`` pure-jnp fallback on CPU (same key-split order, so
    both paths produce bit-identical noise).  Pass ``interpret=True`` to
    force interpret-mode Pallas (kernel validation on CPU).

    ``clip``/``sigma`` may be traced scalars (runtime FLParams — the engine
    sweeps them without recompiling).  The Pallas kernel bakes ``sigma`` as
    a compile-time constant, so a traced sigma is folded into the noise
    operand instead (``x·scale + 1.0·(σ·n)`` — same f32 product, one extra
    elementwise multiply outside the fused pass).  This fold is what lets
    the privacy subsystem's budget schedulers (``repro/privacy``) drive a
    *per-round* σ_t through the fused kernel: scheduler output arrives here
    as ``FLParams.dp_sigma``, a traced value like any other lane.

    Returns (noised_tree, pre_clip_global_norm)."""
    if interpret is None:
        if not pallas_backend_ready():
            return _ref.dp_clip_noise_tree_ref(tree, key, clip, sigma)
        interpret = False
    leaves, treedef = jax.tree.flatten(tree)
    total = sum(
        _dp.sumsq(l.reshape(-1), interpret=interpret) for l in leaves
    )
    norm = jnp.sqrt(total)
    scale = jnp.minimum(1.0, clip / jnp.maximum(norm, 1e-12))
    sigma_static = isinstance(sigma, (int, float))
    keys = jax.random.split(key, len(leaves))
    out = []
    for leaf, k in zip(leaves, keys):
        noise = jax.random.normal(k, leaf.shape, jnp.float32)
        if not sigma_static:
            noise = sigma * noise
        out.append(
            _dp.scale_noise(leaf, noise, scale,
                            sigma=float(sigma) if sigma_static else 1.0,
                            interpret=interpret)
        )
    return jax.tree.unflatten(treedef, out), norm
