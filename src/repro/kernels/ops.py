"""Jit'd public wrappers over the Pallas kernels (stable API for the model
zoo and the FL core).  Each function dispatches to the kernel and is
validated against ``repro.kernels.ref`` in tests/test_kernels.py."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import dp_clip_noise as _dp
from repro.kernels import flash_attention as _fa
from repro.kernels import flash_decode as _fd
from repro.kernels import rglru_scan as _rg


def flash_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                    interpret: bool = True):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               interpret=interpret)


def flash_decode(q, k, v, length, *, interpret: bool = True,
                 return_partials: bool = False):
    return _fd.flash_decode(q, k, v, length, interpret=interpret,
                            return_partials=return_partials)


combine_decode_partials = _fd.combine_partials


def rglru_scan(a, x, h0=None, *, interpret: bool = True):
    return _rg.rglru_scan(a, x, h0, interpret=interpret)


def dp_clip_noise(x, noise, clip: float, sigma: float, *, interpret: bool = True):
    return _dp.dp_clip_noise(x, noise, clip, sigma, interpret=interpret)


def dp_clip_noise_tree(tree, key, clip: float, sigma: float, *,
                       interpret: bool = True):
    """Pytree version with a SHARED global norm (client-level DP contract —
    identical semantics to core.dp.privatize_update(mode='clipped')).

    Returns (noised_tree, pre_clip_global_norm)."""
    leaves, treedef = jax.tree.flatten(tree)
    total = sum(
        _dp.sumsq(l.reshape(-1), interpret=interpret) for l in leaves
    )
    norm = jnp.sqrt(total)
    scale = jnp.minimum(1.0, clip / jnp.maximum(norm, 1e-12))
    keys = jax.random.split(key, len(leaves))
    out = []
    for leaf, k in zip(leaves, keys):
        noise = jax.random.normal(k, leaf.shape, jnp.float32)
        out.append(
            _dp.scale_noise(leaf, noise, scale, sigma=float(sigma),
                            interpret=interpret)
        )
    return jax.tree.unflatten(treedef, out), norm
