"""Flash decode (one query token vs a long KV cache) as a Pallas TPU kernel.

Tiled over KV blocks with online softmax; optionally returns the partial
(o, m, l) triple instead of the normalised output so a *context-parallel*
caller (KV sequence sharded over the ``model`` mesh axis, DESIGN.md §5) can
combine shards with a distributed log-sum-exp:

    m* = max_i m_i ;  l* = Σ_i l_i·e^{m_i−m*} ;  o = Σ_i o_i·l_i·e^{m_i−m*} / l*

All q heads of one batch element are processed per grid step ([HQ, D] tile —
HQ ≤ 128 for every assigned config, so one MXU tile), with the GQA mapping
done by repeating K/V rows across the q-head group inside the kernel.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
            m_scr, l_scr, acc_scr, *, scale: float, bk: int, group: int):
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)          # [HQ, D]
    k = k_ref[0].astype(jnp.float32)          # [bk, HKV, D]
    v = v_ref[0].astype(jnp.float32)
    hq = q.shape[0]
    hkv = k.shape[1]

    # scores per q head: head h attends kv head h // group
    kg = jnp.repeat(k, group, axis=1)          # [bk, HQ, D]
    vg = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("hd,thd->ht", q, kg) * scale  # [HQ, bk]

    valid = (ki * bk + jax.lax.broadcasted_iota(jnp.int32, (hq, bk), 1)) < len_ref[0]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                    # [HQ, bk]
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.einsum("ht,thd->hd", p, vg)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)
        m_ref[0] = m_scr[...]
        l_ref[0] = l_scr[...]


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Backend-routed interpret mode (the dp_clip_noise routing idiom):
    ``None`` resolves to compiled Pallas on TPU and interpret mode on every
    other backend, so the kernel is never silently interpreted on real
    hardware and never fails to lower off-TPU.  An explicit bool wins
    (tests force ``interpret=True`` to validate the kernel on CPU)."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def flash_decode(q, k, v, length, *, bk: int = 256,
                 interpret: Optional[bool] = None,
                 return_partials: bool = False):
    """q: [B,HQ,D]; k,v: [B,T,HKV,D]; length: [B] valid cache prefix.

    Returns [B,HQ,D] (or (o, m, l) partials when return_partials).
    ``interpret=None`` auto-routes by backend (:func:`resolve_interpret`)."""
    return _flash_decode(q, k, v, length, bk=bk,
                         interpret=resolve_interpret(interpret),
                         return_partials=return_partials)


@functools.partial(jax.jit, static_argnames=("bk", "interpret", "return_partials"))
def _flash_decode(q, k, v, length, *, bk: int, interpret: bool,
                  return_partials: bool):
    b, hq, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    bk = min(bk, t)
    assert t % bk == 0, (t, bk)
    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (b,))

    grid = (b, t // bk)
    out, m, l = pl.pallas_call(
        functools.partial(_kernel, scale=1.0 / math.sqrt(d), bk=bk, group=group),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bi, ki: (bi,)),
            pl.BlockSpec((1, hq, d), lambda bi, ki: (bi, 0, 0)),
            pl.BlockSpec((1, bk, hkv, d), lambda bi, ki: (bi, ki, 0, 0)),
            pl.BlockSpec((1, bk, hkv, d), lambda bi, ki: (bi, ki, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, hq, d), lambda bi, ki: (bi, 0, 0)),
            pl.BlockSpec((1, hq, 1), lambda bi, ki: (bi, 0, 0)),
            pl.BlockSpec((1, hq, 1), lambda bi, ki: (bi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, d), q.dtype),
            jax.ShapeDtypeStruct((b, hq, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((hq, 1), jnp.float32),
            pltpu.VMEM((hq, 1), jnp.float32),
            pltpu.VMEM((hq, d), jnp.float32),
        ],
        interpret=interpret,
    )(length, q, k, v)
    if return_partials:
        return out, m[..., 0], l[..., 0]
    return out


def combine_partials(os, ms, ls):
    """Merge per-shard flash-decode partials (leading shard axis).

    os: [S,B,HQ,D] (un-normalised outputs are already normalised per shard,
    so we re-weight by l); ms, ls: [S,B,HQ]."""
    m_star = jnp.max(ms, axis=0)                      # [B,HQ]
    w = ls * jnp.exp(ms - m_star)                     # [S,B,HQ]
    denom = jnp.maximum(jnp.sum(w, axis=0), 1e-30)
    o = jnp.sum(os.astype(jnp.float32) * w[..., None], axis=0) / denom[..., None]
    return o.astype(os.dtype)
