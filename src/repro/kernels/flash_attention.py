"""Flash attention (forward) as a Pallas TPU kernel.

Online-softmax tiling: grid (batch, q_heads, Q/bq, T/bk); the innermost grid
axis walks K/V blocks sequentially (TPU grids are sequential), carrying the
running max ``m``, normaliser ``l`` and un-normalised accumulator in VMEM
scratch.  Q/K/V blocks are staged HBM→VMEM by BlockSpec; the MXU consumes
[bq, d] × [bk, d]^T tiles (d = head_dim ≤ 128, bq = bk = 128 by default —
multiples of the 128-lane MXU).

Supports causal masking, sliding windows and GQA (q head h reads kv head
h // group) directly in the index maps, matching ``ref.flash_attention_ref``.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: Optional[int],
            bq: int, bk: int, seq_q: int, seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # [bq, d]
    k = k_ref[0, 0].astype(jnp.float32)  # [bk, d]
    v = v_ref[0, 0].astype(jnp.float32)  # [bk, d]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [bq, bk]

    # absolute positions (q offset accounts for prefill-with-prefix: t-s)
    rq = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + (seq_k - seq_q)
    rk = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= rk <= rq
    if window is not None:
        mask &= rk > rq - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                    bq: int = 128, bk: int = 128,
                    interpret: Optional[bool] = None):
    """q: [B,S,HQ,D]; k,v: [B,T,HKV,D] -> [B,S,HQ,D].

    ``interpret=None`` auto-routes by backend exactly like
    :func:`repro.kernels.flash_decode.resolve_interpret`: compiled Pallas on
    TPU, interpret mode elsewhere."""
    from repro.kernels.flash_decode import resolve_interpret
    return _flash_attention(q, k, v, causal=causal, window=window, bq=bq,
                            bk=bk, interpret=resolve_interpret(interpret))


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "bq", "bk", "interpret")
)
def _flash_attention(q, k, v, *, causal: bool, window: Optional[int],
                     bq: int, bk: int, interpret: bool):
    b, s, hq, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    bq = min(bq, s)
    bk = min(bk, t)
    assert s % bq == 0 and t % bk == 0, (s, bq, t, bk)

    # layout: [B, H, S, D] blocks
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    grid = (b, hq, s // bq, t // bk)
    out = pl.pallas_call(
        functools.partial(
            _kernel, scale=1.0 / math.sqrt(d), causal=causal, window=window,
            bq=bq, bk=bk, seq_q=s, seq_k=t,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, hi, qi, ki, g=g: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, hi, qi, ki, g=g: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
