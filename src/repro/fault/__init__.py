"""Fault-tolerance subsystem (paper §IV): one namespace for both halves.

* the **checkpoint cost model** and Weibull fitting — host-side analysis,
  lives in ``repro.core.fault`` (the paper's C(t_c), the corrected renewal
  variant, Young/Daly, MLE fitting);
* the **failure-scenario engine** — compiled per-round failure *processes*
  (i.i.d. / Markov-bursty / Weibull-lifetime / straggler) selected by the
  runtime lane code ``FLConfig.fault_process``, with per-client state
  threaded through the engine's scan carry (``repro.fault.process``,
  docs/DESIGN.md §6).
"""
from repro.core.fault import (checkpoint_cost, fit_weibull,
                              optimal_checkpoint_interval, recovery_overhead,
                              weibull_failure_prob)
from repro.fault.process import (PROCESSES, FaultState, arrival_score,
                                 fault_step, iid_fail_times,
                                 init_fault_state, process_code)

__all__ = [
    "PROCESSES", "FaultState", "arrival_score", "checkpoint_cost",
    "fault_step", "fit_weibull", "iid_fail_times", "init_fault_state",
    "optimal_checkpoint_interval", "process_code", "recovery_overhead",
    "weibull_failure_prob",
]
