"""Pluggable failure processes — the failure-scenario engine (paper §IV).

The seed repo modelled client failures as ONE process: an i.i.d. Bernoulli
draw per (client, round).  Surveys of client selection under unreliable
participation (PAPERS.md: Gouissem et al. 2023, Németh et al. 2022) treat
the failure process itself as a scenario axis — outages are bursty and
correlated, hardware lifetimes are Weibull, and stragglers hurt without
ever dying.  This module grows that axis into an engine component:

* ``iid``       (code 0) — per-round Bernoulli(``failure_prob``), bitwise
  the pre-engine behaviour (same keys, same draws — pinned in
  ``tests/test_fault.py``).
* ``markov``    (code 1) — per-client two-state (up/down) Markov chain:
  an outage persists with ``1 − 1/fault_burst`` per round (expected
  outage length ``fault_burst`` rounds) and starts at the rate that makes
  the STATIONARY failure probability equal ``failure_prob``, so the
  marginal matches the i.i.d. process while failures arrive in bursts.
  The entry probability ``p/(L(1−p))`` only exists for
  ``L ≥ p/(1−p)``, so the effective burst length is floored there —
  the configured marginal always holds exactly, even at high rates.
  A client newly entering an outage dies at a uniform local step; a
  client still down at round start contributes nothing (fails at step 0).
* ``weibull``   (code 2) — per-client Weibull lifetimes: each client
  carries an age (rounds since its last failure) and fails with the
  discrete Weibull hazard ``h(a) = 1 − exp((a/λ)^k − ((a+1)/λ)^k)``
  (shape ``k = weibull_shape``; ageing hardware for k > 1).  λ is
  calibrated so the steady-state marginal failure rate is
  ``failure_prob``: the expected cycle length is
  ``Σ_a exp(−(a/λ)^k) ≈ λ·Γ(1+1/k) + ½`` (Euler–Maclaurin), hence
  ``λ = (1/p − ½) / Γ(1+1/k)``.
* ``straggler`` (code 3) — slow clients instead of dead ones: with
  probability ``failure_prob`` a client's round time is stretched by
  ``straggler_slow``×.  The update SURVIVES (``fail_at = local_steps``);
  only the simulated round time moves (``fl_driver.simulate_round_time``
  takes the emitted per-client ``slow`` factors).

The process is selected by the RUNTIME lane code ``FLParams.fault_process``
(like the privacy subsystem's ``dp_sched``): every process is computed
branch-free each round and a ``jnp.where`` chain picks the lane's one, so
a whole (process × rate × seed) frontier compiles ONCE in the sweep engine
(``benchmarks/bench_fault.py`` asserts it).  Per-client process state — the
Markov outage indicator and the Weibull age — is a :class:`FaultState`
carried in ``core/rounds.RoundState`` through the ``lax.scan``; it evolves
by the same rule on every lane (each process only ever reads its own
field), which is what keeps the selection branch-free.

Key discipline (the bitwise pin): the i.i.d. path consumes
``fold_in(k_fail, 1)`` / ``fold_in(k_fail, 2)`` exactly as the pre-engine
round step did; the other processes draw from ``fold_in(k_fail, 3..7)``,
which never perturbs the i.i.d. stream.  Semantics of the emitted failure
times are documented in docs/DESIGN.md §6.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

# Runtime process codes (FLParams.fault_process carries these as f32 lanes).
PROCESSES = ("iid", "markov", "weibull", "straggler")


def process_code(name: str) -> float:
    """Runtime lane value for a failure-process name."""
    return float(PROCESSES.index(name))


class FaultState(NamedTuple):
    """Per-client failure-process state, carried across rounds (all [n] f32).

    Rides in ``core/rounds.RoundState`` so the compiled engine's
    ``lax.scan`` threads it for free; lanes that never read a field still
    evolve it (branch-free), which costs a handful of scalar ops per
    client and keeps the process code a pure runtime value.
    """

    down: jnp.ndarray   # Markov outage indicator (1 = client currently down)
    age: jnp.ndarray    # Weibull age: rounds survived since last failure


def init_fault_state(n: int) -> FaultState:
    return FaultState(down=jnp.zeros((n,), jnp.float32),
                      age=jnp.zeros((n,), jnp.float32))


def iid_fail_times(k_bern, k_step, p, n: int, local_steps: int) -> jnp.ndarray:
    """The pre-engine draw, verbatim: Bernoulli(p) failures at a uniform
    local step; ``local_steps`` for survivors.  Both execution plans route
    their i.i.d. path through this helper with their historical keys, so
    the refactor cannot move a single bit of the default lanes."""
    fails = jax.random.bernoulli(k_bern, p, (n,))
    step = jax.random.randint(k_step, (n,), 0, local_steps)
    return jnp.where(fails, step, local_steps)


def fault_step(state: FaultState, k_fail, pr, n: int,
               local_steps: int) -> Tuple[jnp.ndarray, jnp.ndarray, FaultState]:
    """One round of the failure-scenario engine.

    Returns ``(fail_at [n] i32, slow [n] f32, new_state)``: the local step
    at which each client dies (``local_steps`` = survives), the round-time
    stretch factor (1.0 except for stragglers), and the evolved process
    state.  ``pr`` is the runtime :class:`~repro.configs.base.FLParams` —
    ``fault_process`` selects the process branch-free, so rate/process
    sweeps share one compiled program.  ``k_fail`` is the round step's
    failure key; see the module docstring for the fold_in discipline.
    """
    p = pr.failure_prob

    # --- iid (code 0): bitwise the pre-engine draw --------------------------
    fa_iid = iid_fail_times(jax.random.fold_in(k_fail, 1),
                            jax.random.fold_in(k_fail, 2), p, n, local_steps)

    p_c = jnp.clip(p, 1e-6, 0.999)

    # --- markov (code 1): bursty, correlated outages ------------------------
    # entry prob e = p/(L(1-p)) needs e <= 1, i.e. L >= p/(1-p): shorter
    # bursts cannot realise a stationary rate p, so the effective burst is
    # floored there and the marginal stays exactly failure_prob instead of
    # silently drifting at high rates
    burst = jnp.maximum(jnp.maximum(pr.fault_burst, 1.0),
                        p_c / (1.0 - p_c))
    stay = 1.0 - 1.0 / burst                       # P(down -> down)
    enter = jnp.clip(p_c / (burst * (1.0 - p_c)), 0.0, 1.0)  # P(up -> down)
    u_m = jax.random.uniform(jax.random.fold_in(k_fail, 3), (n,))
    was_down = state.down > 0
    down_next = jnp.where(was_down, u_m < stay, u_m < enter)
    step_m = jax.random.randint(jax.random.fold_in(k_fail, 4), (n,),
                                0, local_steps)
    fa_markov = jnp.where(down_next & ~was_down, step_m,
                          jnp.where(down_next, 0, local_steps))

    # --- weibull (code 2): per-client lifetimes, ageing hazard --------------
    k_w = jnp.maximum(pr.weibull_shape, 0.1)
    gamma_1p = jnp.exp(jax.scipy.special.gammaln(1.0 + 1.0 / k_w))
    lam = jnp.maximum((1.0 / p_c - 0.5) / gamma_1p, 1e-3)
    a = state.age
    hazard = -jnp.expm1((a / lam) ** k_w - ((a + 1.0) / lam) ** k_w)
    u_w = jax.random.uniform(jax.random.fold_in(k_fail, 5), (n,))
    fail_w = u_w < hazard
    step_w = jax.random.randint(jax.random.fold_in(k_fail, 6), (n,),
                                0, local_steps)
    fa_weibull = jnp.where(fail_w, step_w, local_steps)

    # --- straggler (code 3): slow, not dead ---------------------------------
    u_s = jax.random.uniform(jax.random.fold_in(k_fail, 7), (n,))
    straggler = u_s < p
    slow_s = jnp.where(straggler, jnp.maximum(pr.straggler_slow, 1.0), 1.0)

    code = pr.fault_process
    fail_at = jnp.where(
        code < 0.5, fa_iid,
        jnp.where(code < 1.5, fa_markov,
                  jnp.where(code < 2.5, fa_weibull,
                            jnp.full((n,), local_steps, fa_iid.dtype))))
    slow = jnp.where(code > 2.5, slow_s, jnp.ones((n,), jnp.float32))
    new_state = FaultState(down=down_next.astype(jnp.float32),
                           age=jnp.where(fail_w, 0.0, a + 1.0))
    return fail_at, slow, new_state


def arrival_score(slow, compute):
    """Per-client arrival-order score for the ``buffered_async`` plan
    (core/plans.py ``fault_arrivals``): update i arrives in order of
    ``slow_i / compute_i`` — the same straggler/Weibull-process ``slow``
    factors and compute capacities :func:`simulate_round_time`'s per-client
    time uses (its ``steps × base_step_time`` factor scales every client
    equally, so the RANKS agree exactly).  No RNG: arrival order is fully
    driven by the existing failure processes, which is what keeps every
    other lane's key stream untouched."""
    return slow / jnp.maximum(compute, 0.1)


def gather_cohort(fail_at, slow, cohort_idx):
    """Cohort view of one round's process outputs (the population engine,
    ARCHITECTURE.md §Scale): the processes evolve the FULL [n] population
    every round — elementwise vector work that shards over the ``client``
    mesh axis, and the only semantics under which Markov bursts persist
    and Weibull ages accumulate for clients the cohort skipped — while
    training consumes only the gathered ``[k_max]`` rows."""
    return fail_at[cohort_idx], slow[cohort_idx]
