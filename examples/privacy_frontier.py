"""The privacy-budget frontier, compiled (ISSUE 3 walkthrough).

The paper's headline trade-off — higher privacy budgets → less noise →
better accuracy — as a *total-budget* experiment with real accounting:

  1. a TOTAL (ε, δ) budget per run (``dp_budget``), turned into a
     per-round σ by a budget scheduler (``repro/privacy/schedule.py``);
  2. an in-scan RDP accountant composes the actual spend every round and
     **withholds any release that would overshoot the budget** — past
     exhaustion the global model is frozen, like a halted deployment;
  3. budgets AND schedule choices are runtime FLParams lanes, so the whole
     (budget × schedule × seed) frontier below is ONE compiled program.

Run:  PYTHONPATH=src python examples/privacy_frontier.py
"""
import numpy as np

from repro.configs.base import FLConfig
from repro.data.synthetic import make_federated
from repro.privacy import schedule as sched_lib
from repro.train import fl_driver

ROUNDS = 40
BUDGETS = (200.0, 1000.0, 5000.0)
SEEDS = (0, 1)


def main():
    fed = make_federated(0, "unsw", n_samples=6_000, n_clients=20)
    fl = FLConfig(n_clients=20, clients_per_round=6, local_epochs=5,
                  local_batch=32, local_lr=0.08, dp_clip=1.0,
                  dp_scheduled=True, failure_prob=0.05)

    cells = [{"dp_budget": b, "dp_sched": sched_lib.schedule_code(s)}
             for b in BUDGETS for s in ("uniform", "adaptive")]
    m0 = fl_driver.RUNNER_STATS["misses"]
    grid = fl_driver.run_fl_sweep(fed, fl, cells, seeds=SEEDS,
                                  rounds=ROUNDS, eval_every=5)
    compiles = fl_driver.RUNNER_STATS["misses"] - m0

    print(f"== ε-vs-AUC frontier: {len(cells)} cells x {len(SEEDS)} seeds, "
          f"{compiles} compile ==")
    print(f"{'budget':>8} {'schedule':>9} {'acc ε':>9} {'AUC':>6} "
          f"{'σ first→last':>15} {'exhausted at':>12}")
    for cell, row in zip(cells, grid):
        sched = sched_lib.SCHEDULES[int(cell["dp_sched"])]
        auc = float(np.mean([r.auc for r in row]))
        eps = float(np.mean([r.eps_spent for r in row]))
        h = row[0].history
        dead = [r_ for r_, live in zip(h["round"], h["live"]) if live < 1.0]
        print(f"{cell['dp_budget']:8.0f} {sched:>9} {eps:9.1f} {auc:6.3f} "
              f"{h['sigma'][0]:7.4f}→{h['sigma'][-1]:6.4f} "
              f"{('round %d' % dead[0]) if dead else 'never':>12}")

    print("\nReading the frontier:")
    print("  * more budget → smaller calibrated σ → higher AUC (Fig. 3's")
    print("    claim, now under composed accounting, not nominal ε);")
    print("  * 'adaptive' spends budget faster whenever validation AUC")
    print("    stalls (less noise per round) and may exhaust early — the")
    print("    frozen tail shows as a constant accuracy trace;")
    print("  * every row shares one XLA program: dp_budget/dp_sched are")
    print("    runtime lanes, like ε was in examples/dp_tradeoff.py §4.")


if __name__ == "__main__":
    main()
