"""Serving example: batched autoregressive decoding with KV caches.

Loads a reduced assigned architecture, "prefills" a batch of prompts, then
decodes tokens with the rolling/full cache machinery — the same code path
the ``decode_32k`` / ``long_500k`` dry-run shapes lower, at CPU scale.
Demonstrates: greedy sampling, per-request lengths, sliding-window cache for
the long-context variant, and the SSM O(1)-state decode.

Run:  PYTHONPATH=src python examples/serve_decode.py [--arch mamba2_130m]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_arch
from repro.launch.serve import prefill_scan
from repro.models.model import build, effective_window


def serve(arch: str, n_new: int = 16, batch: int = 4, prompt_len: int = 12,
          window: int | None = None):
    cfg = get_arch(arch, smoke=True)
    model = build(cfg)
    params = model.init(jax.random.key(0))
    key = jax.random.key(1)
    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size,
                                 jnp.int32)

    cache_len = prompt_len + n_new
    caches = model.init_cache(batch, cache_len, params=params, window=window)

    decode = jax.jit(
        lambda p, t, c, i: model.decode_step(p, t, c, i, window=window)
    )

    # prefill: the whole prompt in ONE dispatch (lax.scan over the decode
    # path) instead of one dispatch per token — same math, no per-token
    # host round-trip
    t0 = time.time()
    logits, caches = prefill_scan(model, params, prompts, caches,
                                  window=window)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    out = []
    t0 = time.time()
    tok = jnp.argmax(logits[:, :, :cfg.vocab_size], axis=-1).astype(jnp.int32)
    for t in range(prompt_len, prompt_len + n_new):
        out.append(tok)
        logits, caches = decode(params, tok, caches, jnp.asarray(t))
        tok = jnp.argmax(logits[:, :, :cfg.vocab_size], axis=-1).astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"  {arch}: prefill {prompt_len} tokens in {t_prefill:.2f}s; "
          f"decoded {gen.shape} in {t_decode:.2f}s "
          f"({batch * n_new / t_decode:.1f} tok/s on 1 CPU)")
    print(f"  first request: {gen[0].tolist()}")
    return gen


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    args = ap.parse_args()
    archs = [args.arch] if args.arch else [
        "mamba2_130m",          # O(1)-state SSM decode
        "recurrentgemma_9b",    # hybrid: RG-LRU state + rolling window cache
        "granite_3_8b",         # dense GQA full cache
    ]
    for a in archs:
        cfg = get_arch(a, smoke=True)
        w = cfg.sliding_window
        print(f"== {a} (window={w}) ==")
        serve(a, window=w)


if __name__ == "__main__":
    main()
