"""Quickstart: 60 seconds with the framework's public API.

1. Build a reduced assigned architecture and run a forward + train step.
2. Run three FL communication rounds (Algorithm 1: adaptive selection + DP +
   fault tolerance) on the paper's anomaly-detection MLP.
3. Run a full (short) experiment with the compiled engine: the whole round
   loop is one ``lax.scan``, vmapped over 2 seeds — one device program for
   every repeated trial (docs/ARCHITECTURE.md).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig, get_arch
from repro.core import rounds as rounds_lib
from repro.data.synthetic import make_federated, round_batches
from repro.models import mlp as mlp_lib
from repro.models.model import build


def part1_model_zoo():
    print("== 1. model zoo: reduced granite-3-8b, forward + loss ==")
    cfg = get_arch("granite_3_8b", smoke=True)
    model = build(cfg)
    params = model.init(jax.random.key(0))
    batch = {
        "tokens": jnp.ones((2, 32), jnp.int32),
        "labels": jnp.ones((2, 32), jnp.int32),
    }
    logits = model.forward(params, batch)
    loss = model.loss(params, batch)
    print(f"  logits {logits.shape}, loss {float(loss):.3f}")

    # one decode step against a KV cache
    caches = model.init_cache(2, 64)
    step_logits, caches = model.decode_step(
        params, batch["tokens"][:, :1], caches, jnp.asarray(0)
    )
    print(f"  decode logits {step_logits.shape}")


def part2_fl_rounds():
    print("== 2. the paper: three FL rounds with DP + fault tolerance ==")
    fed = make_federated(0, "unsw", n_samples=2_000, n_clients=10)
    fl = FLConfig(n_clients=10, clients_per_round=4, local_epochs=3,
                  local_batch=32, dp_epsilon=50.0, dp_clip=5.0)
    params = mlp_lib.init_mlp(jax.random.key(0), fed.n_features, 64, 2)
    state = rounds_lib.init_round_state(params, fl, jax.random.key(1),
                                        n_clients=fed.n_clients)
    step = jax.jit(rounds_lib.make_parallel_round(mlp_lib.mlp_loss, fl,
                                                  fed.n_clients))
    rng = np.random.default_rng(0)
    for r in range(3):
        batches = jax.tree.map(jnp.asarray,
                               round_batches(rng, fed, fl.local_epochs, fl.local_batch))
        state, m = step(state, batches)
        print(f"  round {r}: K={float(m.k_effective):.0f} selected="
              f"{int(m.sel_mask.sum())} loss={float(m.global_loss):.3f} "
              f"failures={int(m.failed.sum())}")
    acc = mlp_lib.accuracy(state.params, jnp.asarray(fed.test_x),
                           jnp.asarray(fed.test_y))
    print(f"  test accuracy after 3 rounds: {float(acc)*100:.1f}%")


def part3_compiled_engine():
    print("== 3. compiled engine: 15 rounds x 2 seeds as ONE program ==")
    from repro.train import fl_driver

    fed = make_federated(0, "unsw", n_samples=2_000, n_clients=10)
    fl = FLConfig(n_clients=10, clients_per_round=4, local_epochs=3,
                  local_batch=32, dp_epsilon=50.0, dp_clip=5.0)
    results = fl_driver.run_fl_batch(fed, fl, "proposed", seeds=(0, 1),
                                     rounds=15, eval_every=5)
    for r in results:
        print(f"  seed {r.seed}: acc={r.accuracy*100:.1f}% auc={r.auc:.3f} "
              f"sim_time={r.sim_time_s:.1f}s eps_spent={r.eps_spent:.2f}")


if __name__ == "__main__":
    part1_model_zoo()
    part2_fl_rounds()
    part3_compiled_engine()
