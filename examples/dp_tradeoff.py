"""Privacy/utility trade-off walkthrough (paper Fig. 3 + beyond-paper DP).

Shows (all ε figures via the privacy subsystem's RDP accountant,
``repro/privacy`` — the paper reports only the nominal per-release budget):

  1. the paper's mechanism (fixed-σ noise on raw updates) vs our hardened
     mode (clip + analytic-σ + RDP accounting) on the same federation,
     with the ACCOUNTED composed ε printed next to the paper's nominal ε,
  2. how the composed ε grows with rounds (`compose_epsilon` — the
     closed-form constant-σ composition; the old per-round Python
     accumulation loop is gone, the accountant API is the one path),
  3. calibrating σ to hit a TOTAL ε budget over the whole run
     (``noise_multiplier_for_budget``) — the deployment-correct workflow,
     automated end-to-end by ``dp_scheduled`` configs
     (examples/privacy_frontier.py),
  4. the sweep engine: the whole ε grid of (1) as ONE compiled program —
     ε is a runtime FLParams lane, so N budgets cost one compile
     (``run_fl_sweep``; EXPERIMENTS.md §Sweeps).

Run:  PYTHONPATH=src python examples/dp_tradeoff.py
"""
import dataclasses

import numpy as np

from repro.configs.base import FLConfig
from repro.core.dp import gaussian_sigma
from repro.data.synthetic import make_federated
from repro.privacy import compose_epsilon, noise_multiplier_for_budget
from repro.train.fl_driver import run_fl, run_fl_sweep

ROUNDS = 40


def main():
    fed = make_federated(0, "unsw", n_samples=6_000, n_clients=20)
    base = FLConfig(n_clients=20, clients_per_round=6, local_epochs=5,
                    local_batch=32, local_lr=0.08, dp_clip=5.0,
                    failure_prob=0.05)

    print("== 1. paper mode (fixed sigma, no clip) vs clipped mode ==")
    print("   (nominal = the paper's per-release label; accounted = RDP-")
    print("    composed ε over the executed rounds)")
    for mode, sig in (("paper", 0.005), ("paper", 0.02), ("clipped", None)):
        fl = dataclasses.replace(
            base, dp_mode=mode, dp_sigma=sig or 0.01, dp_epsilon=50.0)
        r = run_fl(fed, fl, "proposed", seed=0, rounds=ROUNDS, eval_every=10)
        label = f"{mode}(sigma={sig})" if mode == "paper" else "clipped(eps=50/round)"
        nominal = "sigma-only" if mode == "paper" else "eps=50/release"
        print(f"  {label:26s} acc={r.accuracy*100:5.1f}% auc={r.auc:.3f}  "
              f"nominal {nominal:>15s} | accounted eps={r.eps_spent:10.2f}")

    print("\n== 2. composed epsilon over rounds (accountant API) ==")
    sigma = gaussian_sigma(50.0, 1e-5, 5.0)
    z = sigma / 5.0
    for r in range(10, ROUNDS + 1, 10):
        eps = compose_epsilon(z, q=6 / 20, steps=r, delta=1e-5)
        print(f"  after {r:3d} rounds: eps = {eps:8.2f} "
              f"(per-release eps was 50)")

    print("\n== 3. calibrate to a TOTAL budget (the deployment workflow) ==")
    for eps_total in (8.0, 20.0, 50.0):
        z = noise_multiplier_for_budget(eps_total, 1e-5, ROUNDS, q=6 / 20)
        spent = compose_epsilon(z, 6 / 20, ROUNDS, 1e-5)
        print(f"  total eps={eps_total:5.1f} over {ROUNDS} rounds -> "
              f"noise multiplier z={z:.3f} (sigma={z*5.0:.3f} at clip=5, "
              f"accounted eps={spent:.2f})")
    print("  (dp_scheduled=True configs run this calibration inside the "
          "compiled program\n   and halt at exhaustion — see "
          "examples/privacy_frontier.py)")

    print("\n== 4. an epsilon GRID as one compiled sweep program ==")
    fl = dataclasses.replace(base, dp_mode="clipped")
    epsilons = (10.0, 50.0, 200.0, 1000.0)
    grid = run_fl_sweep(fed, fl, [{"dp_epsilon": e} for e in epsilons],
                        seeds=(0, 1), rounds=ROUNDS, eval_every=10)
    for eps, row in zip(epsilons, grid):
        acc = np.mean([r.accuracy for r in row])
        print(f"  nominal eps/round={eps:7.1f}  acc={acc*100:5.1f}% "
              f"(accounted eps={row[0].eps_spent:9.2f}, {len(row)} seeds, "
              f"same program as every other row)")


if __name__ == "__main__":
    main()
