"""Privacy/utility trade-off walkthrough (paper Fig. 3 + beyond-paper DP).

Shows:
  1. the paper's mechanism (fixed-σ noise on raw updates) vs our hardened
     mode (clip + analytic-σ + RDP accounting) on the same federation,
  2. the composed ε over rounds from the RDP accountant (the paper reports
     only the per-release budget),
  3. calibrating σ to hit a TOTAL ε budget over the whole run
     (``noise_multiplier_for_budget``) — the deployment-correct workflow,
  4. the sweep engine: the whole ε grid of (1) as ONE compiled program —
     ε is a runtime FLParams lane, so N budgets cost one compile
     (``run_fl_sweep``; docs/ARCHITECTURE.md §Sweeps).

Run:  PYTHONPATH=src python examples/dp_tradeoff.py
"""
import dataclasses

import numpy as np

from repro.configs.base import FLConfig
from repro.core.dp import (RdpAccountant, gaussian_sigma,
                           noise_multiplier_for_budget)
from repro.data.synthetic import make_federated
from repro.train.fl_driver import run_fl, run_fl_sweep

ROUNDS = 40


def main():
    fed = make_federated(0, "unsw", n_samples=6_000, n_clients=20)
    base = FLConfig(n_clients=20, clients_per_round=6, local_epochs=5,
                    local_batch=32, local_lr=0.08, dp_clip=5.0,
                    failure_prob=0.05)

    print("== 1. paper mode (fixed sigma, no clip) vs clipped mode ==")
    for mode, sig in (("paper", 0.005), ("paper", 0.02), ("clipped", None)):
        fl = dataclasses.replace(
            base, dp_mode=mode, dp_sigma=sig or 0.01, dp_epsilon=50.0)
        r = run_fl(fed, fl, "proposed", seed=0, rounds=ROUNDS, eval_every=10)
        label = f"{mode}(sigma={sig})" if mode == "paper" else "clipped(eps=50/round)"
        print(f"  {label:26s} acc={r.accuracy*100:5.1f}% auc={r.auc:.3f}")

    print("\n== 2. composed epsilon over rounds (RDP accountant) ==")
    sigma = gaussian_sigma(50.0, 1e-5, 5.0)
    z = sigma / 5.0
    acct = RdpAccountant(1e-5)
    for r in range(ROUNDS):
        acct.step(z, q=6 / 20)
        if (r + 1) % 10 == 0:
            print(f"  after {r+1:3d} rounds: eps = {acct.epsilon():8.2f} "
                  f"(per-release eps was 50)")

    print("\n== 3. calibrate to a TOTAL budget (the deployment workflow) ==")
    for eps_total in (8.0, 20.0, 50.0):
        z = noise_multiplier_for_budget(eps_total, 1e-5, ROUNDS, q=6 / 20)
        print(f"  total eps={eps_total:5.1f} over {ROUNDS} rounds -> "
              f"noise multiplier z={z:.3f} (sigma={z*5.0:.3f} at clip=5)")

    print("\n== 4. an epsilon GRID as one compiled sweep program ==")
    fl = dataclasses.replace(base, dp_mode="clipped")
    epsilons = (10.0, 50.0, 200.0, 1000.0)
    grid = run_fl_sweep(fed, fl, [{"dp_epsilon": e} for e in epsilons],
                        seeds=(0, 1), rounds=ROUNDS, eval_every=10)
    for eps, row in zip(epsilons, grid):
        acc = np.mean([r.accuracy for r in row])
        print(f"  eps/round={eps:7.1f}  acc={acc*100:5.1f}% "
              f"(composed eps={row[0].eps_spent:9.2f}, {len(row)} seeds, "
              f"same program as every other row)")


if __name__ == "__main__":
    main()
