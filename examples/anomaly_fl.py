"""End-to-end driver (deliverable b): the paper's use case, full pipeline.

Trains the anomaly-detection MLP with federated learning for a few hundred
rounds on the synthetic UNSW-NB15 stand-in, comparing our method against the
paper's baselines, with server-side checkpointing at the Weibull-optimal
interval, recovery, and a final Mann-Whitney significance test.  Each
method's repeated seeds run as one compiled scan/vmap program
(fl_driver.run_fl_batch; see docs/ARCHITECTURE.md).

Run:  PYTHONPATH=src python examples/anomaly_fl.py [--rounds 200] [--dataset road]
"""
import argparse
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import Checkpointer
from repro.configs.base import FLConfig
from repro.core.fault import optimal_checkpoint_interval
from repro.data.synthetic import make_federated
from repro.train import fl_driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--dataset", choices=["unsw", "road"], default="unsw")
    ap.add_argument("--clients", type=int, default=40)
    ap.add_argument("--seeds", type=int, default=3)
    args = ap.parse_args()

    print(f"== federation: {args.dataset}, {args.clients} clients, "
          f"{args.rounds} rounds ==")
    fed = make_federated(0, args.dataset,
                         n_samples=12_000 if args.dataset == "unsw" else 2_000,
                         n_clients=args.clients, alpha=0.5)
    print(f"  client sizes: min={fed.data_sizes().min():.0f} "
          f"max={fed.data_sizes().max():.0f}; "
          f"label entropies: {fed.label_entropy()[:6].round(2)} ...")

    # Weibull-optimal checkpoint cadence (corrected cost model; the paper's
    # literal model is degenerate — see core/fault.py)
    t_c = optimal_checkpoint_interval(T=3600, t_r=30, lam=600, k=1.2,
                                      write_cost=2.0)
    print(f"  optimal checkpoint interval t_c* = {t_c:.0f}s "
          f"(~every {max(1, int(t_c / 18)):d} rounds at 18s/round)")

    fl = FLConfig(
        n_clients=args.clients, clients_per_round=8, rounds=args.rounds,
        local_epochs=5, local_batch=32, local_lr=0.08,
        dp_enabled=True, dp_mode="clipped", dp_epsilon=50.0, dp_clip=5.0,
        fault_tolerance=True, failure_prob=0.05,
    )

    results = {}
    for method in ("proposed", "acfl", "fedl2p"):
        # all seeds of the method run as ONE compiled scan/vmap program
        per_seed = fl_driver.run_fl_batch(
            fed, fl, method, seeds=range(args.seeds), rounds=args.rounds,
            eval_every=max(args.rounds // 10, 5), dataset=args.dataset)
        accs = [r.accuracy for r in per_seed]
        aucs = [r.auc for r in per_seed]
        ts = [r.sim_time_s for r in per_seed]
        results[method] = per_seed
        print(f"  {method:10s} acc={np.mean(accs)*100:5.1f}% "
              f"auc={np.mean(aucs):.3f} time(sim)={np.mean(ts):6.1f}s "
              f"eps_spent={per_seed[0].eps_spent:.1f}")

    # significance (paper Table III) — shared helper, repro/stats.py
    from repro.stats import mannwhitney_greater

    a = [x for r in results["proposed"] for x in r.history["auc"][-3:]]
    for base in ("acfl", "fedl2p"):
        b = [x for r in results[base] for x in r.history["auc"][-3:]]
        u, p, sig = mannwhitney_greater(a, b)
        print(f"  Mann-Whitney proposed vs {base}: U={u:.0f} p={p:.2e} "
              f"{'(significant)' if sig else '(ns)'}")

    # demonstrate checkpoint save/restore round-trip on the final model
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2, interval_rounds=1)
        params = results["proposed"][0]  # RunResult — save its history params?
        # save the final global params of the first seed
        from repro.models.mlp import init_mlp

        final = init_mlp(jax.random.key(0), fed.n_features, 64, 2)
        path = ck.maybe_save(args.rounds, final, {"note": "final global model"})
        rnd, restored = ck.restore_latest(final)
        same = jax.tree.all(jax.tree.map(
            lambda x, y: bool(jnp.allclose(x, y)), final, restored))
        print(f"  checkpoint round-trip at round {rnd}: ok={bool(same)}")


if __name__ == "__main__":
    main()
