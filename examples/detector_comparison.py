"""Model-pluggable engine demo: three detector architectures, one engine.

The compiled engine resolves the detector from the STATIC
``FLConfig.model`` field (``models/spec.py`` registry), so comparing
architectures is three configs — each compiles its own program once and
rides the identical sweep/privacy machinery:

* ``mlp``   — the paper's flattened-feature MLP (the default);
* ``cnn``   — 1-D CNN over raw CAN windows (window-native);
* ``rglru`` — recurrent RG-LRU detector on the same raw windows.

The federation is the raw-window ROAD variant
(``make_federated(dataset="road_raw")``): x stays flat for the data path,
``feature_shape=(window, n_signals)`` tells window-native specs how to
unflatten.

Run:  PYTHONPATH=src python examples/detector_comparison.py
Env:  REPRO_EXAMPLE_FULL=1 for a longer run (more rounds/clients/seeds);
      the default is a tiny-rounds smoke suitable for CI.
"""
import dataclasses
import os

import numpy as np

from repro.configs.base import FLConfig
from repro.data.synthetic import make_federated
from repro.train import fl_driver

FULL = os.environ.get("REPRO_EXAMPLE_FULL", "0") == "1"
N_CLIENTS = 16 if FULL else 8
N_SAMPLES = 2_400 if FULL else 900
ROUNDS = 60 if FULL else 8
SEEDS = (0, 1, 2) if FULL else (0, 1)
MODELS = ("mlp", "cnn", "rglru")


def main():
    print(f"== detector comparison on raw ROAD windows "
          f"({'full' if FULL else 'smoke'}: {ROUNDS} rounds, "
          f"{len(SEEDS)} seeds) ==")
    fed = make_federated(0, "road_raw", n_samples=N_SAMPLES,
                         n_clients=N_CLIENTS)
    print(f"  federation: {fed.n_clients} clients, "
          f"{fed.n_features} features = windows {fed.feature_shape}")
    fl = FLConfig(n_clients=N_CLIENTS, clients_per_round=max(3, N_CLIENTS // 4),
                  local_epochs=3, local_batch=32, local_lr=0.08,
                  dp_enabled=True, dp_mode="clipped", dp_epsilon=1000.0,
                  dp_clip=1.0, fault_tolerance=True)

    for model in MODELS:
        cfg = dataclasses.replace(fl, model=model)
        res = fl_driver.run_fl_batch(fed, cfg, "proposed", seeds=SEEDS,
                                     rounds=ROUNDS, eval_every=max(ROUNDS // 2, 1))
        auc = float(np.mean([r.auc for r in res]))
        acc = float(np.mean([r.accuracy for r in res]))
        print(f"  {model:6s} auc={auc:.3f} acc={acc * 100:5.1f}% "
              f"eps={res[0].eps_spent:8.1f} "
              f"(one compile, {len(SEEDS)} lanes)")
    print("  (window-native detectors see [window, signals] structure the "
          "flattened MLP destroys; benchmarks/bench_models.py records the "
          "gated comparison)")


if __name__ == "__main__":
    main()
