"""Fault-tolerance walkthrough (paper §IV + Table II).

Shows:
  1. the paper's Weibull failure model and the checkpoint-interval cost
     curve — including the degeneracy of the paper's literal C(t_c) and the
     corrected renewal model (core/fault.py docstring),
  2. fitting (λ, k) from simulated historical failure data,
  3. the failure-scenario engine (repro/fault, docs/DESIGN.md §6): every
     failure process × rate as runtime lanes of ONE compiled sweep program
     — i.i.d. losses, Markov bursty outages, Weibull lifetimes, and
     stragglers that slow rounds without killing updates — with the
     reliability coupling feeding failures back into client selection,
  4. the Table-II robustness argument: with vs without fault tolerance at
     a stress failure rate,
  5. client-level checkpoint recovery via the Checkpointer.

Run:  PYTHONPATH=src python examples/fault_tolerance.py
"""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import Checkpointer
from repro.configs.base import FLConfig
from repro.fault import (PROCESSES, checkpoint_cost, fit_weibull,
                         optimal_checkpoint_interval, process_code,
                         weibull_failure_prob)
from repro.data.synthetic import make_federated
from repro.train import fl_driver


def main():
    print("== 1. checkpoint-interval cost model ==")
    T, t_r, lam, k = 3600.0, 30.0, 600.0, 1.2
    for t_c in (5, 30, 120, 600):
        c_paper = float(checkpoint_cost(t_c, T, t_r, lam, k))
        c_fixed = float(checkpoint_cost(t_c, T, t_r, lam, k, write_cost=2.0))
        print(f"  t_c={t_c:4d}s  C_paper={c_paper:.4f}  C_corrected={c_fixed:.4f}")
    print("  paper's literal C(t_c) is increasing -> argmin at t_c->0 (degenerate);")
    tc = optimal_checkpoint_interval(T, t_r, lam, k, write_cost=2.0)
    print(f"  corrected renewal model: t_c* = {tc:.1f}s "
          f"(Young/Daly sqrt(2*w*MTBF) ~= {np.sqrt(2*2.0*600):.1f}s)")

    print("\n== 2. fitting Weibull(λ, k) from failure history ==")
    rng = np.random.default_rng(0)
    history = lam * rng.weibull(k, 400)
    lam_hat, k_hat = fit_weibull(history)
    print(f"  true (λ={lam:.0f}, k={k}) -> fitted (λ={lam_hat:.0f}, k={k_hat:.2f})")
    print(f"  p_f within t_c*={tc:.0f}s: "
          f"{float(weibull_failure_prob(tc, lam_hat, k_hat)):.3f}")

    print("\n== 3. failure-scenario frontier: one compiled program ==")
    fed = make_federated(0, "unsw", n_samples=5_000, n_clients=20)
    base = FLConfig(n_clients=20, clients_per_round=6, local_epochs=5,
                    local_batch=32, local_lr=0.08, dp_enabled=True,
                    dp_mode="clipped", dp_epsilon=50.0, dp_clip=5.0,
                    fault_tolerance=True)
    rates = (0.05, 0.35)
    # every (process × rate) is a RUNTIME lane (fault_process sweeps like
    # dp_sched) with the selection coupling on: the whole grid below
    # compiles ONCE and runs as one vmapped program
    cells = [{"fault_process": process_code(p), "failure_prob": r,
              "fault_util_w": 1.0} for p in PROCESSES for r in rates]
    sweep = fl_driver.run_fl_sweep(fed, base, cells, seeds=(0, 1),
                                   rounds=30, eval_every=15)
    print(f"  {'process':>10s} {'p_fail':>7s} {'acc%':>6s} {'fail_obs':>9s} "
          f"{'time(sim)':>10s}")
    for cell, row in zip(cells, sweep):
        acc = np.mean([r.accuracy for r in row])
        fail = np.mean([x for r in row for x in r.history["fail"]])
        t = np.mean([r.sim_time_s for r in row])
        print(f"  {PROCESSES[int(cell['fault_process'])]:>10s} "
              f"{cell['failure_prob']:7.2f} {acc*100:6.1f} {fail:9.3f} "
              f"{t:10.1f}")
    print("  (stragglers: fail_obs = 0 but time grows — slow, not dead)")

    print("\n== 4. robustness under failures, with vs without FT (Table II) ==")
    print(f"  {'p_fail':>7s} {'FT acc%':>8s} {'noFT acc%':>10s} "
          f"{'FT time':>8s} {'noFT time':>10s}")
    for pf in (0.05, 0.35):
        flc = dataclasses.replace(base, failure_prob=pf)
        r_ft = fl_driver.run_fl(fed, flc, "proposed", seed=0, rounds=30,
                                eval_every=15)
        r_no = fl_driver.run_fl(fed, flc, "proposed_noft", seed=0, rounds=30,
                                eval_every=15)
        print(f"  {pf:7.2f} {r_ft.accuracy*100:8.1f} {r_no.accuracy*100:10.1f} "
              f"{r_ft.sim_time_s:8.1f} {r_no.sim_time_s:10.1f}")

    print("\n== 5. checkpoint write/restore (client recovery protocol) ==")
    from repro.models.mlp import init_mlp

    params = init_mlp(jax.random.key(0), fed.n_features, 64, 2)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=3, interval_rounds=2)
        for rnd in range(9):
            ck.maybe_save(rnd, params, {"round": rnd})
        rnd, restored = ck.restore_latest(params)
        ok = jax.tree.all(jax.tree.map(lambda a, b: bool(jnp.allclose(a, b)),
                                       params, restored))
        print(f"  saved every 2 rounds, kept {len(ck._list())}, "
              f"restored round {rnd}, bitwise ok={bool(ok)}")


if __name__ == "__main__":
    main()
