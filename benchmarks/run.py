"""Benchmark orchestrator — discovers and runs every ``bench_*.py``.

Prints ``name,us_per_call,derived`` CSV lines at the end (harness contract);
the human-readable tables stream as each section runs.

  engine — legacy Python-loop driver vs compiled scan/vmap engine
           (writes BENCH_engine.json at the repo root)
  sweep  — one-program-per-sweep vs one-program-per-cell
           (writes BENCH_sweep.json at the repo root)
  privacy— ε-vs-AUC budget frontier (adaptive scheduling, one program)
           + accountant overhead (writes BENCH_privacy.json)
  fault  — failure-process frontier (iid/markov/weibull/straggler × rate,
           one program) + FT robustness gate (writes BENCH_fault.json)
  models — pluggable-detector grid: flattened MLP vs window-native CNN /
           RG-LRU on raw ROAD windows (writes BENCH_models.json)
  serve  — streaming anomaly scoring: bucketed double-buffered engine vs
           naive per-window loop (writes BENCH_serve.json)
  scale  — population-scale cohort engine, sublinear-wall gate
           (writes BENCH_scale.json)
  table1 — method comparison (paper Table I)
  table2 — fault tolerance ablation (paper Table II)
  fig3   — privacy budget sweep (paper Fig. 3)
  table3 — Mann-Whitney U significance (paper Table III)
  kernels— per-kernel CPU-interpret timings vs jnp oracle
  roofline — summarised from dry-run artifacts (if present)

Any ``benchmarks/bench_*.py`` not in the preferred order above is picked up
automatically (alphabetically, after the known ones) as long as it exposes
``run(csv_rows) -> report``.

Flags:

* ``--smoke``   — export every ``REPRO_*_SMOKE=1`` BEFORE importing the
  bench modules (they size their grids at import time), shrinking the run
  to CI scale.  ``bench_engine`` has no smoke knob and runs as-is.
* ``--only a,b``— run only the named benches (e.g. ``--only sweep,serve``).
* ``--list``    — print the discovered benches and exit.
* ``--profile [LOGDIR]`` — wrap the whole run in ``jax.profiler`` via
  ``repro.obs.profile_trace``; view with ``tensorboard --logdir LOGDIR``.

Exit code: non-zero if any bench raised OR any *gated* acceptance flag in a
bench's report came back false (each ``GATES`` entry names the pass flag
and the ``gated`` switch inside the report; smoke grids un-gate wall-clock
verdicts, so ``--smoke`` runs gate correctness only).  Store write-through
happens inside each bench (``benchmarks/common.record_bench``); regression
detection against that history is ``tools/bench_regress.py``'s job, not
ours.

Env: REPRO_FULL=1 for the paper's full 40-client/200-round/10-seed setting.
"""
from __future__ import annotations

import argparse
import contextlib
import importlib
import os
import time
import traceback

# benches whose import-time grid sizing reads a smoke env var
SMOKE_VARS = (
    "REPRO_SWEEP_SMOKE", "REPRO_PRIVACY_SMOKE", "REPRO_FAULT_SMOKE",
    "REPRO_MODELS_SMOKE", "REPRO_SCALE_SMOKE", "REPRO_SERVE_SMOKE",
    "REPRO_ASYNC_SMOKE",
)

# canonical run order; discovery appends anything new alphabetically
PREFERRED_ORDER = (
    "engine", "sweep", "privacy", "fault", "models", "serve", "scale",
    "table1", "table2", "fig3", "table3",
)

# report-dict gates: bench -> list of (pass_flag_path, gated_switch_path).
# A None switch means always gated.  Paths are dotted keys into the report.
GATES = {
    "engine": [("acceptance.pass_under_2x", None)],
    "sweep": [("acceptance.pass_warm_not_slower", "acceptance.gated")],
    "privacy": [("overhead.pass_within_5pct", "overhead.gated")],
    "fault": [("coupling_gate.coupling_saves_time", "coupling_gate.gated")],
    "async": [("async_gate.async_beats_sync", "async_gate.gated")],
    "models": [("road_raw_auc.window_native_matches_or_beats_mlp",
                "road_raw_auc.gated")],
    "serve": [("gate.all_models_pass", "gate.gated")],
    "scale": [("sublinear.ok", None)],
}


def discover() -> list:
    """Every ``benchmarks/bench_*.py``: preferred order first, new last."""
    here = os.path.dirname(os.path.abspath(__file__))
    found = sorted(f[len("bench_"):-len(".py")] for f in os.listdir(here)
                   if f.startswith("bench_") and f.endswith(".py"))
    ordered = [n for n in PREFERRED_ORDER if n in found]
    ordered += [n for n in found if n not in PREFERRED_ORDER]
    return ordered


def _dig(report, path):
    cur = report
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def check_gates(name: str, report) -> list:
    """Failed gated acceptance flags of ``report`` -> list of messages."""
    if not isinstance(report, dict):
        return []
    failures = []
    for flag_path, gated_path in GATES.get(name, ()):
        flag = _dig(report, flag_path)
        if flag is None:        # section absent (e.g. future report reshape)
            continue
        gated = True if gated_path is None else bool(_dig(report, gated_path))
        if gated and not flag:
            failures.append(f"{name}: gate {flag_path} is false")
    return failures


def _bench_kernels(csv_rows):
    """Interpret-mode kernels vs oracles: correctness + relative walltime.

    (Wall-times on CPU interpret mode are NOT TPU perf — they are recorded
    to track regressions in kernel complexity only.)
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops, ref

    print("\n== Kernel micro-bench (interpret mode, correctness-oriented) ==")
    key = jax.random.key(0)
    b, s, hq, hkv, d = 1, 256, 4, 2, 64
    q = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hq, d))
    k = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, d))
    v = jax.random.normal(jax.random.fold_in(key, 3), (b, s, hkv, d))

    def timed(name, fn, *a, n=3, **kw):
        fn(*a, **kw)  # compile
        t0 = time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(fn(*a, **kw))
        us = (time.perf_counter() - t0) / n * 1e6
        print(f"  {name:28s} {us:12.0f} us/call")
        csv_rows.append((f"kernels/{name}", us, 0.0))

    timed("flash_attention[pallas]", lambda: ops.flash_attention(q, k, v))
    timed("flash_attention[ref]", lambda: ref.flash_attention_ref(q, k, v))
    ln = jnp.array([s])
    qd = q[:, 0]
    timed("flash_decode[pallas]", lambda: ops.flash_decode(qd, k, v, ln))
    timed("flash_decode[ref]", lambda: ref.flash_decode_ref(qd, k, v, ln))
    x = jax.random.normal(jax.random.fold_in(key, 4), (65536,))
    nz = jax.random.normal(jax.random.fold_in(key, 5), (65536,))
    timed("dp_clip_noise[pallas]", lambda: ops.dp_clip_noise(x, nz, 1.0, 0.1))
    timed("dp_clip_noise[ref]", lambda: ref.dp_clip_noise_ref(x, nz, 1.0, 0.1))
    a_ = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(key, 6), (1, 512, 128)))
    x_ = jax.random.normal(jax.random.fold_in(key, 7), (1, 512, 128))
    timed("rglru_scan[pallas]", lambda: ops.rglru_scan(a_, x_))
    timed("rglru_scan[ref]", lambda: ref.rglru_scan_ref(a_, x_))


def _roofline_summary(csv_rows):
    # roofline summary (dry-run artifacts, if the sweep has been run)
    try:
        from benchmarks import roofline

        arts = roofline.load_artifacts()
        if arts:
            print(f"\n== Roofline summary ({len(arts)} dry-run artifacts) ==")
            doms = {}
            for a in arts:
                r = roofline.analyse(a)
                doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
                csv_rows.append(
                    (f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
                     f"{('/' + r['tag']) if r['tag'] else ''}",
                     max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"]) * 1e6,
                     r["mfu_bound"]),
                )
            print("  dominant-term histogram:", doms)
        else:
            print("\n(no dry-run artifacts; run python -m repro.launch.dryrun --all)")
    except Exception as e:  # noqa: BLE001
        print("roofline summary skipped:", e)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="benchmarks.run", description="run the benchmark suite")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-scale grids: set every REPRO_*_SMOKE=1 before "
                         "bench modules import")
    ap.add_argument("--only", default=None, metavar="A,B",
                    help="comma-separated bench names (see --list)")
    ap.add_argument("--list", action="store_true", dest="list_only",
                    help="print discovered benches and exit")
    ap.add_argument("--profile", nargs="?", const="profiles/bench",
                    default=None, metavar="LOGDIR",
                    help="dump a jax.profiler trace of the run "
                         "(TensorBoard-loadable; default LOGDIR "
                         "profiles/bench)")
    args = ap.parse_args(argv)

    benches = discover()
    if args.list_only:
        for n in benches:
            gates = ", ".join(f for f, _ in GATES.get(n, ())) or "-"
            print(f"{n:10s} gates: {gates}")
        return 0

    if args.only:
        wanted = [w.strip() for w in args.only.split(",") if w.strip()]
        unknown = sorted(set(wanted) - set(benches))
        if unknown:
            ap.error(f"unknown bench(es) {unknown}; known: {benches}")
        benches = [n for n in benches if n in wanted]

    # smoke vars must be in the environment before the bench modules import:
    # every bench_*.py sizes its grid at module scope.
    if args.smoke:
        for var in SMOKE_VARS:
            os.environ[var] = "1"

    if args.profile:
        from repro.obs import profile_trace
        prof = profile_trace(args.profile)
    else:
        prof = contextlib.nullcontext()

    csv_rows = []
    failures = []
    t0 = time.time()
    with prof:
        for name in benches:
            try:
                mod = importlib.import_module(f"benchmarks.bench_{name}")
                report = mod.run(csv_rows)
            except Exception:  # noqa: BLE001 — keep the rest of the suite alive
                traceback.print_exc()
                failures.append(f"{name}: raised (see traceback above)")
                continue
            failures.extend(check_gates(name, report))
        _bench_kernels(csv_rows)
        _roofline_summary(csv_rows)
    if args.profile:
        print(f"\nprofiler trace -> {args.profile} "
              f"(tensorboard --logdir {args.profile})")

    print(f"\ntotal benchmark time: {time.time() - t0:.1f}s")
    print("\nname,us_per_call,derived")
    for name, us, derived in csv_rows:
        print(f"{name},{us:.3f},{derived}")

    if failures:
        print("\nFAILED benches/gates:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nall benches and gates passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
