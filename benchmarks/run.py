"""Benchmark orchestrator — one section per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV lines at the end (harness contract);
the human-readable tables stream as each section runs.

  engine — legacy Python-loop driver vs compiled scan/vmap engine
           (writes BENCH_engine.json at the repo root)
  sweep  — one-program-per-sweep vs one-program-per-cell
           (writes BENCH_sweep.json at the repo root)
  privacy— ε-vs-AUC budget frontier (adaptive scheduling, one program)
           + accountant overhead (writes BENCH_privacy.json)
  fault  — failure-process frontier (iid/markov/weibull/straggler × rate,
           one program) + FT robustness gate (writes BENCH_fault.json)
  models — pluggable-detector grid: flattened MLP vs window-native CNN /
           RG-LRU on raw ROAD windows (writes BENCH_models.json)
  serve  — streaming anomaly scoring: bucketed double-buffered engine vs
           naive per-window loop (writes BENCH_serve.json)
  table1 — method comparison (paper Table I)
  table2 — fault tolerance ablation (paper Table II)
  fig3   — privacy budget sweep (paper Fig. 3)
  table3 — Mann-Whitney U significance (paper Table III)
  kernels— per-kernel CPU-interpret timings vs jnp oracle
  roofline — summarised from dry-run artifacts (if present)

The paper tables run every uncached (method, dataset) GRID as one compiled
program (run_fl_sweep — runtime hyper-parameter lanes); see EXPERIMENTS.md
§Sweeps.

Env: REPRO_FULL=1 for the paper's full 40-client/200-round/10-seed setting.
"""
from __future__ import annotations

import sys
import time


def _bench_kernels(csv_rows):
    """Interpret-mode kernels vs oracles: correctness + relative walltime.

    (Wall-times on CPU interpret mode are NOT TPU perf — they are recorded
    to track regressions in kernel complexity only.)
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops, ref

    print("\n== Kernel micro-bench (interpret mode, correctness-oriented) ==")
    key = jax.random.key(0)
    b, s, hq, hkv, d = 1, 256, 4, 2, 64
    q = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hq, d))
    k = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, d))
    v = jax.random.normal(jax.random.fold_in(key, 3), (b, s, hkv, d))

    def timed(name, fn, *a, n=3, **kw):
        fn(*a, **kw)  # compile
        t0 = time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(fn(*a, **kw))
        us = (time.perf_counter() - t0) / n * 1e6
        print(f"  {name:28s} {us:12.0f} us/call")
        csv_rows.append((f"kernels/{name}", us, 0.0))

    timed("flash_attention[pallas]", lambda: ops.flash_attention(q, k, v))
    timed("flash_attention[ref]", lambda: ref.flash_attention_ref(q, k, v))
    ln = jnp.array([s])
    qd = q[:, 0]
    timed("flash_decode[pallas]", lambda: ops.flash_decode(qd, k, v, ln))
    timed("flash_decode[ref]", lambda: ref.flash_decode_ref(qd, k, v, ln))
    x = jax.random.normal(jax.random.fold_in(key, 4), (65536,))
    nz = jax.random.normal(jax.random.fold_in(key, 5), (65536,))
    timed("dp_clip_noise[pallas]", lambda: ops.dp_clip_noise(x, nz, 1.0, 0.1))
    timed("dp_clip_noise[ref]", lambda: ref.dp_clip_noise_ref(x, nz, 1.0, 0.1))
    a_ = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(key, 6), (1, 512, 128)))
    x_ = jax.random.normal(jax.random.fold_in(key, 7), (1, 512, 128))
    timed("rglru_scan[pallas]", lambda: ops.rglru_scan(a_, x_))
    timed("rglru_scan[ref]", lambda: ref.rglru_scan_ref(a_, x_))


def main() -> None:
    csv_rows = []
    t0 = time.time()

    from benchmarks import (bench_engine, bench_fault, bench_models,
                            bench_privacy, bench_serve, bench_sweep,
                            bench_table1, bench_table2, bench_table3,
                            bench_fig3)

    bench_engine.run(csv_rows)
    bench_sweep.run(csv_rows)
    bench_privacy.run(csv_rows)
    bench_fault.run(csv_rows)
    bench_models.run(csv_rows)
    bench_serve.run(csv_rows)
    bench_table1.run(csv_rows)
    bench_table2.run(csv_rows)
    bench_fig3.run(csv_rows)
    bench_table3.run(csv_rows)
    _bench_kernels(csv_rows)

    # roofline summary (dry-run artifacts, if the sweep has been run)
    try:
        from benchmarks import roofline

        arts = roofline.load_artifacts()
        if arts:
            print(f"\n== Roofline summary ({len(arts)} dry-run artifacts) ==")
            doms = {}
            for a in arts:
                r = roofline.analyse(a)
                doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
                csv_rows.append(
                    (f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
                     f"{('/' + r['tag']) if r['tag'] else ''}",
                     max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"]) * 1e6,
                     r["mfu_bound"]),
                )
            print("  dominant-term histogram:", doms)
        else:
            print("\n(no dry-run artifacts; run python -m repro.launch.dryrun --all)")
    except Exception as e:  # noqa: BLE001
        print("roofline summary skipped:", e)

    print(f"\ntotal benchmark time: {time.time() - t0:.1f}s")
    print("\nname,us_per_call,derived")
    for name, us, derived in csv_rows:
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()
