"""Table II — impact of fault tolerance.

Paper: enabling checkpoint-based fault tolerance costs a little accuracy
(94.8→92.1 on UNSW) and time (570→600s) but keeps training alive under
client failures.  We run ours with/without FT at the paper's 5% failure rate
and additionally at a 25% stress rate, where the robustness benefit (the
reason FT exists) becomes visible in final accuracy.  The failure
probability is a runtime FLParams lane: each method's {5%, 25%} pair runs
as ONE compiled sweep program per dataset (fault_tolerance itself is a
STATIC boolean — it gates code structure, so with/without FT are separate
programs by design).
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import N_SEEDS, base_fl, run_sweep_cells

DATASETS = ("unsw", "road")
FAIL_CELLS = (("default", 0.05), ("failp25", 0.25))


def run(csv_rows: list):
    seeds = range(N_SEEDS)
    rows = {}  # (method, dataset, tag) -> result dicts
    for ds in DATASETS:
        for method in ("proposed", "proposed_noft"):
            cells = [(tag, dataclasses.replace(base_fl(), failure_prob=p))
                     for tag, p in FAIL_CELLS]
            by_tag = run_sweep_cells(method, ds, cells, seeds=seeds)
            for tag, rs in by_tag.items():
                rows[(method, ds, tag)] = rs

    def mean(method, ds, tag, field):
        rs = rows[(method, ds, tag)]
        return sum(r[field] for r in rs) / len(rs)

    print("\n== Table II: fault tolerance (means over seeds) ==")
    print(f"{'dataset':8s} {'config':28s} {'acc%':>7s} {'auc':>7s} {'time(s,sim)':>12s}")
    for ds in DATASETS:
        for label, m, tag in (
            ("without FT (p_f=5%)", "proposed_noft", "default"),
            ("with FT (p_f=5%)", "proposed", "default"),
            ("without FT (p_f=25%)", "proposed_noft", "failp25"),
            ("with FT (p_f=25%)", "proposed", "failp25"),
        ):
            acc = mean(m, ds, tag, "accuracy") * 100
            auc = mean(m, ds, tag, "auc")
            t = mean(m, ds, tag, "sim_time_s")
            print(f"{ds:8s} {label:28s} {acc:7.1f} {auc:7.3f} {t:12.1f}")
            csv_rows.append((f"table2/{ds}/{label.replace(' ', '_')}/acc_pct", t * 1e6, acc))
    for ds in DATASETS:
        t_ft = mean("proposed", ds, "default", "sim_time_s")
        t_no = mean("proposed_noft", ds, "default", "sim_time_s")
        print(f"claim[{ds}]: FT adds overhead at low p_f -> {t_ft > t_no} "
              f"({t_ft:.0f}s vs {t_no:.0f}s)")
    return [r for rs in rows.values() for r in rs]


if __name__ == "__main__":
    run([])
