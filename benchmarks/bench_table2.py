"""Table II — impact of fault tolerance.

Paper: enabling checkpoint-based fault tolerance costs a little accuracy
(94.8→92.1 on UNSW) and time (570→600s) but keeps training alive under
client failures.  We run ours with/without FT at the paper's 5% failure rate
and additionally at a 25% stress rate, where the robustness benefit (the
reason FT exists) becomes visible in final accuracy.  Seeds per cell run
batched through the scan/vmap engine (benchmarks/common.py).
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import base_fl, mean_of, run_grid

DATASETS = ("unsw", "road")


def run(csv_rows: list):
    rows_ft = run_grid(["proposed"], DATASETS, tag="default")
    rows_noft = run_grid(["proposed_noft"], DATASETS, tag="default")
    stress = dataclasses.replace(base_fl(), failure_prob=0.25)
    rows_ft_hi = run_grid(["proposed"], DATASETS, fl=stress, tag="failp25")
    rows_noft_hi = run_grid(["proposed_noft"], DATASETS, fl=stress, tag="failp25")

    print("\n== Table II: fault tolerance (means over seeds) ==")
    print(f"{'dataset':8s} {'config':28s} {'acc%':>7s} {'auc':>7s} {'time(s,sim)':>12s}")
    for ds in DATASETS:
        for label, rows, m in (
            ("without FT (p_f=5%)", rows_noft, "proposed_noft"),
            ("with FT (p_f=5%)", rows_ft, "proposed"),
            ("without FT (p_f=25%)", rows_noft_hi, "proposed_noft"),
            ("with FT (p_f=25%)", rows_ft_hi, "proposed"),
        ):
            acc = mean_of(rows, m, ds, "accuracy") * 100
            auc = mean_of(rows, m, ds, "auc")
            t = mean_of(rows, m, ds, "sim_time_s")
            print(f"{ds:8s} {label:28s} {acc:7.1f} {auc:7.3f} {t:12.1f}")
            csv_rows.append((f"table2/{ds}/{label.replace(' ', '_')}/acc_pct", t * 1e6, acc))
    for ds in DATASETS:
        t_ft = mean_of(rows_ft, "proposed", ds, "sim_time_s")
        t_no = mean_of(rows_noft, "proposed_noft", ds, "sim_time_s")
        print(f"claim[{ds}]: FT adds overhead at low p_f -> {t_ft > t_no} "
              f"({t_ft:.0f}s vs {t_no:.0f}s)")
    return rows_ft + rows_noft + rows_ft_hi + rows_noft_hi


if __name__ == "__main__":
    run([])
