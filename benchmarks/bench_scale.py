"""Population-scale benchmark (ISSUE 6): the cohort engine's sublinear wall.

Sections, written to ``BENCH_scale.json`` at the repo root:

* ``populations`` — one entry per population size (1k / 10k / 100k
  clients full; 256 / 1k / 4k smoke): lazy generation time, cold compile
  wall, and the warm per-round wall as a min-of-N execute (repo timing
  protocol — never a single cold run).  Each ≥100k-client entry is the
  acceptance criterion's end-to-end round: generation → on-device cohort
  selection → gathered training → eval readback.
* ``sublinear`` — the headline gate: warm per-round wall must grow far
  slower than the population.  The cohort plan's per-round COMPUTE is
  O(k_max) (selection and the failure processes are the only O(N) terms,
  and they are elementwise vector ops), so a 100× population may cost
  only the O(N) vector sliver more — the gate asserts
  ``wall(N_hi)/wall(N_lo) < (N_hi/N_lo) / 5`` (i.e. at least 5× better
  than linear scaling end to end).
* ``memory`` — DESIGN.md §7 accounting vs XLA: the resident per-client
  bytes predicted by ``core/scale.py`` next to the compiled program's
  measured ``argument_size_in_bytes``, plus the auto-chunk policy's
  decision at a representative budget.
* always-on correctness: exactly ONE runner-cache miss per population
  shape (single-compile), repeat calls hit; the smallest and largest
  populations produce finite accuracies.

``REPRO_SCALE_SMOKE=1`` shrinks the populations and round counts for CI;
every assertion stays on.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.configs.base import FLConfig
from repro.core import scale as scale_lib
from repro.data.synthetic import make_population
from repro.train import fl_driver

from benchmarks import common

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_scale.json")

SMOKE = os.environ.get("REPRO_SCALE_SMOKE", "0") == "1"
POPULATIONS = (256, 1_024, 4_096) if SMOKE else (1_000, 10_000, 100_000)
ROUNDS = 4 if SMOKE else 8
K_MAX = 8 if SMOKE else 16
MEMBERS = 16 if SMOKE else 32
POOL = 2_000 if SMOKE else 8_000
WARM_N = 2 if SMOKE else 3
SEEDS = (0,) if SMOKE else (0, 1)


def scale_fl(n: int) -> FLConfig:
    return FLConfig(
        n_clients=n, clients_per_round=K_MAX, k_max=K_MAX, rounds=ROUNDS,
        local_epochs=2, local_batch=32, local_lr=0.08,
        fault_tolerance=True, failure_prob=0.05,
    )


def run(csv_rows: list) -> dict:
    report = {"engine_rev": common.ENGINE_REV, "smoke": SMOKE,
              "device": jax.devices()[0].device_kind,
              "n_devices": jax.device_count(),
              "rounds": ROUNDS, "k_max": K_MAX, "seeds": list(SEEDS)}

    misses0 = fl_driver.RUNNER_STATS["misses"]
    rows = []
    for n in POPULATIONS:
        fl = scale_fl(n)
        t0 = time.time()
        pop = make_population(0, n_clients=n, pool_samples=POOL,
                              members_per_client=MEMBERS)
        gen_s = time.time() - t0

        def run_pop():
            return fl_driver.run_fl_population(
                pop, fl, seeds=SEEDS, rounds=ROUNDS, eval_every=ROUNDS)

        t0 = time.time()
        res = run_pop()
        cold_s = time.time() - t0
        warm, walls = common.warm_min(run_pop, WARM_N)
        acc = float(np.mean([r.accuracy for r in res[0]]))
        assert np.isfinite(acc), f"non-finite accuracy at N={n}"
        rows.append({
            "n_clients": n,
            "gen_s": round(gen_s, 4),
            "cold_s": round(cold_s, 4),
            "warm_s": round(warm, 5),
            "warm_round_s": round(warm / ROUNDS, 6),
            "warm_walls_s": [round(w, 5) for w in walls],
            "accuracy": acc,
            "resident_bytes": scale_lib.population_resident_bytes(
                n, MEMBERS, len(SEEDS)),
        })
    report["populations"] = rows

    # single-compile: one runner miss per population SHAPE; the warm re-runs
    # above were all cache hits
    misses = fl_driver.RUNNER_STATS["misses"] - misses0
    assert misses == len(POPULATIONS), (
        f"expected one compile per population shape "
        f"({len(POPULATIONS)}), saw {misses}")
    report["runner_stats"] = dict(fl_driver.RUNNER_STATS)

    # the sublinear gate: end-to-end per-round wall must beat linear
    # scaling by at least 5x across the full population span
    lo, hi = rows[0], rows[-1]
    pop_ratio = hi["n_clients"] / lo["n_clients"]
    wall_ratio = hi["warm_round_s"] / max(lo["warm_round_s"], 1e-9)
    gate = wall_ratio < pop_ratio / 5.0
    report["sublinear"] = {
        "pop_ratio": pop_ratio,
        "wall_ratio": round(wall_ratio, 3),
        "bound": pop_ratio / 5.0,
        "ok": bool(gate),
    }
    assert gate, (
        f"population engine wall is not sublinear: {wall_ratio:.1f}x wall "
        f"for {pop_ratio:.0f}x clients (bound {pop_ratio / 5.0:.1f}x)")

    # DESIGN.md §7 accounting vs the compiled program's measured inputs
    n_big = rows[-1]["n_clients"]
    budget = 256 * 1024 * 1024
    report["memory"] = {
        "n_clients": n_big,
        "population_data_bytes": scale_lib.population_data_bytes(
            n_big, MEMBERS),
        "carry_bytes_per_lane": scale_lib.population_carry_bytes(n_big),
        "selection_transient_bytes": scale_lib.selection_transient_bytes(
            n_big),
        "cohort_batch_bytes": scale_lib.cohort_batch_bytes(
            K_MAX, 2, 32, 42),
        "auto_chunks_at_256MiB": scale_lib.auto_chunks(
            n_big, budget, MEMBERS, len(SEEDS)),
    }

    with open(OUT, "w") as f:
        json.dump(report, f, indent=1)
    print(json.dumps(report["sublinear"], indent=1))
    print(f"wrote {OUT}")

    # experiment-store write-through (docs/DESIGN.md §8): one cell per
    # population, warm wall gated (lower-better), plus the sublinear ratio
    common.record_bench("scale", [
        {"lane_key": f"pop{r['n_clients']}",
         "statics_key": common.statics_key(scale_fl(r["n_clients"])),
         "wall_cold_s": r["cold_s"], "warm_walls": r["warm_walls_s"],
         "lane_params": {"n_clients": r["n_clients"], "rounds": ROUNDS,
                         "k_max": K_MAX, "seeds": list(SEEDS)},
         "metrics": {"accuracy": r["accuracy"],
                     "gen_s": r["gen_s"],
                     "warm_round_s": r["warm_round_s"]}}
        for r in rows
    ] + [
        {"lane_key": "sublinear",
         "lane_params": {"pop_ratio": report["sublinear"]["pop_ratio"]},
         "metrics": {"wall_ratio": (report["sublinear"]["wall_ratio"], -1),
                     "ok": float(report["sublinear"]["ok"])}}
    ], mode="smoke" if SMOKE else "full")

    for r in rows:
        csv_rows.append((f"scale/pop{r['n_clients']}/warm_round",
                         r["warm_round_s"] * 1e6, r["accuracy"]))
    return report


def main():
    run([])


if __name__ == "__main__":
    main()
