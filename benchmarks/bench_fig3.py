"""Fig. 3 — privacy budget (ε) vs accuracy/loss trade-off.

Paper: UNSW accuracy 86%→89% as ε goes 10→100 (loss 3→2.5); ROAD 73%→82%
(loss 10→9).  Claim validated here: accuracy increases monotonically-ish and
loss decreases as ε grows (less noise), on both datasets.  The whole ε
column of a dataset runs as ONE compiled sweep program — ε is a runtime
FLParams lane, so the grid pays a single compile (benchmarks/common.py
``run_sweep_cells``; see EXPERIMENTS.md §Sweeps).
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import N_SEEDS, base_fl, run_sweep_cells

EPSILONS = (30.0, 100.0, 300.0, 1000.0)
DATASETS = ("unsw", "road")


def run(csv_rows: list):
    print("\n== Fig. 3: privacy budget sweep (one program per dataset) ==")
    print(f"{'dataset':8s} {'eps/round':>9s} {'acc%':>7s} {'auc':>7s} {'final loss':>11s}")
    seeds = range(max(2, N_SEEDS // 2))
    results = {}
    for ds in DATASETS:
        cells = [(f"eps{eps}", dataclasses.replace(base_fl(), dp_epsilon=eps))
                 for eps in EPSILONS]
        by_tag = run_sweep_cells("proposed", ds, cells, seeds=seeds)
        accs = []
        for eps in EPSILONS:
            rows = by_tag[f"eps{eps}"]
            acc = sum(r["accuracy"] for r in rows) / len(rows) * 100
            auc = sum(r["auc"] for r in rows) / len(rows)
            loss = sum(r["history"]["loss"][-1] for r in rows) / len(rows)
            print(f"{ds:8s} {eps:9.1f} {acc:7.1f} {auc:7.3f} {loss:11.3f}")
            csv_rows.append((f"fig3/{ds}/eps{eps}/acc_pct", 0.0, acc))
            accs.append(acc)
        results[ds] = accs
        ok = accs[-1] > accs[0]
        print(f"claim[{ds}]: higher eps (less noise) -> higher accuracy: {ok} "
              f"({accs[0]:.1f}% @eps={EPSILONS[0]} vs {accs[-1]:.1f}% @eps={EPSILONS[-1]})")
    return results


if __name__ == "__main__":
    run([])
