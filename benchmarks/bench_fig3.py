"""Fig. 3 — privacy budget (ε) vs accuracy/loss trade-off.

Paper: UNSW accuracy 86%→89% as ε goes 10→100 (loss 3→2.5); ROAD 73%→82%
(loss 10→9).  Claim validated here: accuracy increases monotonically-ish and
loss decreases as ε grows (less noise), on both datasets.  Each ε point runs
its seeds as one compiled batch (benchmarks/common.py).
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import N_SEEDS, base_fl, mean_of, run_grid

EPSILONS = (30.0, 100.0, 300.0, 1000.0)
DATASETS = ("unsw", "road")


def run(csv_rows: list):
    print("\n== Fig. 3: privacy budget sweep ==")
    print(f"{'dataset':8s} {'eps/round':>9s} {'acc%':>7s} {'auc':>7s} {'final loss':>11s}")
    results = {}
    for ds in DATASETS:
        accs = []
        for eps in EPSILONS:
            fl = dataclasses.replace(base_fl(), dp_epsilon=eps)
            rows = run_grid(["proposed"], [ds], seeds=range(max(2, N_SEEDS // 2)),
                            fl=fl, tag=f"eps{eps}")
            acc = mean_of(rows, "proposed", ds, "accuracy") * 100
            auc = mean_of(rows, "proposed", ds, "auc")
            loss = sum(r["history"]["loss"][-1] for r in rows) / len(rows)
            print(f"{ds:8s} {eps:9.1f} {acc:7.1f} {auc:7.3f} {loss:11.3f}")
            csv_rows.append((f"fig3/{ds}/eps{eps}/acc_pct", 0.0, acc))
            accs.append(acc)
        results[ds] = accs
        ok = accs[-1] > accs[0]
        print(f"claim[{ds}]: higher eps (less noise) -> higher accuracy: {ok} "
              f"({accs[0]:.1f}% @eps={EPSILONS[0]} vs {accs[-1]:.1f}% @eps={EPSILONS[-1]})")
    return results


if __name__ == "__main__":
    run([])
