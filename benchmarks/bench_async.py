"""Execution-plan benchmark (ISSUE 9): the plan frontier + the async gate.

Sections, written to ``BENCH_async.json`` at the repo root:

* ``frontier`` — the full execution-plan frontier: every registered
  client_parallel-family plan (synchronous flat FedAvg, ``buffered_async``
  at ≥2 buffer sizes K, two-tier ``hierarchical``) × the two fault lanes
  where plans separate (bursty Markov outages and stragglers), all as
  runtime lanes of ONE compiled program — the concrete plan is the
  ``FLParams.plan_code`` lane the core/plans registry derives, so a mixed
  sync × async × hier sweep costs exactly one ``_get_runner`` miss (hard
  assertion, like bench_fault's process frontier).  Warm walls are
  min-of-N executes (repo timing protocol).
* ``async_gate`` — the headline claim, gated by the same Mann-Whitney
  helper Table III and the fault coupling gate use (``repro/stats.py``):
  under bursty-outage and straggler lanes, ``buffered_async`` accumulates
  significantly LESS simulated wall time than synchronous
  ``client_parallel`` (p < 0.05 across seeds, one-sided U test) at
  equal-or-better AUC (the sync arm's AUC must NOT significantly exceed
  the async arm's).  Both arms are lanes of one compiled program by
  construction — the comparison can never be an apples-to-oranges
  recompile.
* always-on correctness: on straggler lanes the K-th-arrival time model
  must beat waiting for the slowest client for every K < cohort; the
  hierarchical lane's two cheap edge hops must undercut the flat WAN hop.

``REPRO_ASYNC_SMOKE=1`` shrinks the grid and skips the significance
gate's exit code — the compile-count and plan-semantics assertions stay
on.  CI runs the smoke lane and uploads the artifact
(.github/workflows/ci.yml REPRO_ASYNC_SMOKE job); the store write-through
(``common.record_bench``) makes ``tools/bench_regress.py`` gate the warm
walls and the AUC direction across runs.
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from repro.configs.base import FLConfig
from repro.data.synthetic import make_federated
from repro.fault import process_code
from repro.stats import mannwhitney_greater
from repro.train import fl_driver

from benchmarks import common

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_async.json")

SMOKE = os.environ.get("REPRO_ASYNC_SMOKE", "0") == "1"
N_CLIENTS = 8 if SMOKE else 24
N_SAMPLES = 1_200 if SMOKE else 6_000
ROUNDS = 10 if SMOKE else 50
SEEDS = (0, 1) if SMOKE else (0, 1, 2, 3)
EVAL_EVERY = 5 if SMOKE else 10
WARM_N = 2 if SMOKE else 3
RATE = 0.3 if SMOKE else 0.4         # failure/straggle probability
BURST = 6.0                           # markov expected outage length
SLOW = 8.0                            # straggler stretch factor
BUFFERS = (2.0,) if SMOKE else (2.0, 4.0)   # K of K-of-cohort aggregation
STALENESS_POW = 0.5
# the gate pools both fault lanes over its own (wider) seed set
GATE_SEEDS = (0, 1) if SMOKE else tuple(range(8))
GATE_ROUNDS = 10 if SMOKE else 40
GATE_K = 2.0

FAULT_LANES = (("markov", {"fault_process": process_code("markov"),
                           "fault_burst": BURST}),
               ("straggler", {"fault_process": process_code("straggler"),
                              "straggler_slow": SLOW}))


def _bench_config(**kw) -> FLConfig:
    return FLConfig(
        n_clients=N_CLIENTS, clients_per_round=max(4, N_CLIENTS // 3),
        rounds=ROUNDS, local_epochs=5, local_batch=32, local_lr=0.08,
        fault_tolerance=True, failure_prob=RATE, **kw)


def _plan_variants():
    """(label, runtime-override dict) per client_parallel-family plan."""
    variants = [("sync", {})]
    variants += [(f"async_k{int(k)}",
                  {"plan": "buffered_async", "async_buffer": k,
                   "async_staleness_pow": STALENESS_POW}) for k in BUFFERS]
    variants.append(("hier", {"plan": "hierarchical"}))
    return variants


def run(csv_rows: list) -> dict:
    mode = "smoke" if SMOKE else "full"
    print(f"\n== Async: execution-plan frontier + wall-time gate ({mode}) ==")
    fed = make_federated(0, "unsw", n_samples=N_SAMPLES, n_clients=N_CLIENTS)
    fl = _bench_config()
    variants = _plan_variants()
    cells = [{**plan_kw, **fault_kw, "failure_prob": RATE}
             for _, plan_kw in variants for _, fault_kw in FAULT_LANES]
    labels = [(pl, fa) for pl, _ in variants for fa, _ in FAULT_LANES]

    # ---- frontier: every (plan × fault lane) as runtime lanes, ONE compile
    fl_driver._RUNNER_CACHE.clear()
    m0 = fl_driver.RUNNER_STATS["misses"]
    sweep, t_cold = common.timed_call(
        lambda: fl_driver.run_fl_sweep(fed, fl, cells, seeds=SEEDS,
                                       rounds=ROUNDS, eval_every=EVAL_EVERY),
        label="async.frontier_cold")
    misses = fl_driver.RUNNER_STATS["misses"] - m0
    assert misses == 1, (
        f"the whole (plan x fault x K x seed) frontier must compile exactly "
        f"one runner — the registry maps same-family plans onto one static "
        f"program — got {misses}")

    def warm():
        fl_driver.run_fl_sweep(fed, fl, cells, seeds=SEEDS, rounds=ROUNDS,
                               eval_every=EVAL_EVERY)

    t_warm, warm_walls = common.warm_min(warm, WARM_N)
    assert fl_driver.RUNNER_STATS["misses"] - m0 == 1, \
        "warm frontier reruns must be pure cache hits"

    frontier = []
    by_lane = {}
    for (plan_label, fault_label), row in zip(labels, sweep):
        entry = {
            "plan": plan_label,
            "fault": fault_label,
            "acc_mean": float(np.mean([r.accuracy for r in row])),
            "auc_mean": float(np.mean([r.auc for r in row])),
            "sim_time_mean": float(np.mean([r.sim_time_s for r in row])),
        }
        frontier.append(entry)
        by_lane[(plan_label, fault_label)] = entry

    # plan-semantics assertions on the straggler lane
    sync_t = by_lane[("sync", "straggler")]["sim_time_mean"]
    for k in BUFFERS:
        assert by_lane[(f"async_k{int(k)}", "straggler")]["sim_time_mean"] \
            < sync_t, (
            f"K={k:.0f}-th arrival must undercut waiting for the slowest "
            "straggler")
    assert by_lane[("hier", "straggler")]["sim_time_mean"] < sync_t, \
        "two edge hops at hier_comm_frac each must undercut the flat WAN hop"

    # ---- async gate: buffered_async vs sync, pooled fault lanes ----------
    # Both arms (and both fault lanes) are runtime lanes of ONE program.
    gate_cells = [{**fault_kw, "failure_prob": RATE, **arm_kw}
                  for _, fault_kw in FAULT_LANES
                  for arm_kw in ({}, {"plan": "buffered_async",
                                      "async_buffer": GATE_K,
                                      "async_staleness_pow": STALENESS_POW})]
    mg = fl_driver.RUNNER_STATS["misses"]
    gate_sweep = fl_driver.run_fl_sweep(fed, fl, gate_cells, seeds=GATE_SEEDS,
                                        rounds=GATE_ROUNDS,
                                        eval_every=EVAL_EVERY)
    assert fl_driver.RUNNER_STATS["misses"] - mg <= 1, \
        "the gate grid must be at most one compile"
    sync_rows = [gate_sweep[i] for i in range(0, len(gate_cells), 2)]
    async_rows = [gate_sweep[i] for i in range(1, len(gate_cells), 2)]
    t_sync = [r.sim_time_s for row in sync_rows for r in row]
    t_async = [r.sim_time_s for row in async_rows for r in row]
    auc_sync = [r.auc for row in sync_rows for r in row]
    auc_async = [r.auc for row in async_rows for r in row]
    u, p_time, time_sig = mannwhitney_greater(t_sync, t_async)
    # equal-or-better AUC: sync must NOT be significantly better
    _, p_auc, auc_worse = mannwhitney_greater(auc_sync, auc_async)
    gate = bool(time_sig and not auc_worse)

    n_lanes = len(cells) * len(SEEDS)
    report = {
        "mode": mode,
        "config": {"n_clients": N_CLIENTS, "rounds": ROUNDS,
                   "seeds": list(SEEDS), "rate": RATE, "burst": BURST,
                   "straggler_slow": SLOW, "buffers": list(BUFFERS),
                   "staleness_pow": STALENESS_POW, "n_lanes": n_lanes,
                   "dataset": "unsw", "backend": jax.default_backend(),
                   "n_devices": len(jax.devices())},
        "frontier": {
            "wall_s_cold": t_cold,
            "warm_execute_s_min": t_warm,
            "warm_execute_s_all": warm_walls,
            "warm_n": WARM_N,
            "runner_compiles": misses,
            "cells": frontier,
        },
        "async_gate": {
            "fault_lanes": [name for name, _ in FAULT_LANES],
            "rate": RATE,
            "buffer_k": GATE_K,
            "rounds": GATE_ROUNDS,
            "seeds": list(GATE_SEEDS),
            "sim_time_sync": t_sync,
            "sim_time_async": t_async,
            "auc_sync": auc_sync,
            "auc_async": auc_async,
            "mannwhitney_u": u,
            "p_value_time": p_time,
            "p_value_auc_sync_better": p_auc,
            "async_beats_sync": gate,
            "gated": not SMOKE,
        },
    }
    with open(OUT, "w") as f:
        json.dump(report, f, indent=1)

    common.record_bench("async", [
        {"lane_key": "frontier", "statics_key": common.statics_key(fl),
         "wall_cold_s": t_cold, "warm_walls": warm_walls,
         "lane_params": {"n_lanes": n_lanes, "rounds": ROUNDS,
                         "buffers": list(BUFFERS)},
         "metrics": {"runner_compiles": float(misses)}},
    ] + [
        {"lane_key": f"{e['plan']}@{e['fault']}",
         "statics_key": common.statics_key(fl),
         "lane_params": {"plan": e["plan"], "fault": e["fault"],
                         "rate": RATE},
         "metrics": {"auc_mean": (e["auc_mean"], 1),
                     "acc_mean": e["acc_mean"],
                     "sim_time_mean": e["sim_time_mean"]}}
        for e in frontier
    ] + [
        {"lane_key": "async_gate", "statics_key": common.statics_key(fl),
         "lane_params": {"buffer_k": GATE_K, "rate": RATE,
                         "rounds": GATE_ROUNDS},
         "metrics": {"p_value_time": p_time,
                     "async_beats_sync": float(gate)}},
    ], mode=mode)

    print(f"  frontier x{n_lanes} lanes: {t_cold:7.2f}s cold, "
          f"{t_warm:.2f}s warm (min-of-{WARM_N}), 1 compile")
    for e in frontier:
        print(f"    {e['plan']:>9s} on {e['fault']:>9s}: "
              f"acc={e['acc_mean']:.3f} auc={e['auc_mean']:.3f} "
              f"time={e['sim_time_mean']:7.1f}s")
    print(f"  async gate (K={GATE_K:.0f}, pooled markov+straggler, "
          f"{len(GATE_SEEDS)} seeds): sim time {np.mean(t_async):.1f}s vs "
          f"sync {np.mean(t_sync):.1f}s -> Mann-Whitney p={p_time:.3e}, "
          f"AUC {np.mean(auc_async):.3f} vs {np.mean(auc_sync):.3f} "
          f"(sync-better p={p_auc:.2f}) -> "
          f"{'PASS' if gate else 'ns'}"
          f"{' (not gated in smoke)' if SMOKE else ''}")
    print(f"  -> {os.path.abspath(OUT)}")

    csv_rows.append(("async/frontier_cold_s", t_cold * 1e6,
                     n_lanes * ROUNDS / t_cold))
    csv_rows.append(("async/gate_p_time", 0.0, p_time))
    return report


if __name__ == "__main__":
    # Standalone (and CI) entry: compile-count and plan-semantics
    # assertions raise always; the Mann-Whitney wall-time gate exits
    # nonzero only in full mode (smoke grids are too small to gate on).
    report = run([])
    ag = report["async_gate"]
    if ag["gated"] and not ag["async_beats_sync"]:
        raise SystemExit(
            f"async gate failed: buffered_async does not beat synchronous "
            f"client_parallel on simulated wall time at equal-or-better "
            f"AUC (time p={ag['p_value_time']:.3e}, "
            f"sync-better-AUC p={ag['p_value_auc_sync_better']:.3e})")
