"""Streaming-serving benchmark (ISSUE 7): the tentpole perf claim.

Trains each grid detector with the compiled FL engine, persists it through
``save_serving_checkpoint``, rebuilds a :class:`~repro.serve.ServeEngine`
from the checkpoint alone, and measures the serving hot path on a replayed
test-window stream.  Written to ``BENCH_serve.json`` at the repo root:

* per (model, bucket): **windows/sec** and **p50/p99 per-window latency**
  (a window's latency is its batch's wall), warm min-of-N;
* per model: the naive baseline — one synchronous batch-1 ``predict_proba``
  dispatch per window, the pre-engine serving idiom;
* the **gate** (full mode): batched + double-buffered serving at the
  largest bucket must be ≥5× the naive per-window loop on every grid
  model.

Hard assertions (both modes):

* exactly ONE scorer compile per (model, bucket) — ``SERVE_STATS`` misses
  move only during warmup, never during a timed run;
* served scores across the whole stream are bitwise equal to the compiled
  same-route ``predict_proba`` reference on the same windows.

Timing protocol (repo memory: very noisy wall clocks): warm min-of-N via
``benchmarks/common.warm_min`` — compile and checkpoint I/O happen before
any timed call; training/compile seconds are recorded separately,
unaudited.

``REPRO_SERVE_SMOKE=1`` shrinks the stream and skips the 5x gate
(bitwise + compile-count assertions stay on).
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.data.synthetic import make_federated
from repro.models.spec import get_model_spec, meta_for
from repro.serve import engine as serve_engine
from repro.serve.engine import ServeEngine, save_serving_checkpoint
from repro.train import fl_driver

from benchmarks import common

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")

SMOKE = os.environ.get("REPRO_SERVE_SMOKE", "0") == "1"
BUCKETS = (16, 128)
GRID = (("unsw", "mlp"), ("road_raw", "cnn"))
ROUNDS = 4 if SMOKE else 20
N_CLIENTS = 6 if SMOKE else 10
N_SAMPLES = 1_000 if SMOKE else 2_400
STREAM_WINDOWS = 512 if SMOKE else 8_192   # windows per timed stream pass
CHUNK = 37                                 # awkward arrival-burst size
NAIVE_WINDOWS = 64 if SMOKE else 384       # the naive loop is the slow part
WARM_N = 1 if SMOKE else 3
GATE_X = 5.0


def _train_engine(tmp: str, dataset: str, model: str) -> tuple:
    fed = make_federated(0, dataset, n_samples=N_SAMPLES,
                         n_clients=N_CLIENTS)
    fl = FLConfig(n_clients=N_CLIENTS, clients_per_round=4, rounds=ROUNDS,
                  local_epochs=2, local_batch=32, local_lr=0.08,
                  dp_enabled=False, fault_tolerance=False, model=model)
    t0 = time.time()
    res = fl_driver.run_fl(fed, fl, "random", seed=0, rounds=ROUNDS,
                           eval_every=max(ROUNDS // 2, 1), dataset=dataset,
                           return_params=True)
    train_s = time.time() - t0
    path = save_serving_checkpoint(os.path.join(tmp, f"{model}_{dataset}"),
                                   res.params, model, meta_for(fed))
    return fed, path, train_s, float(res.auc)


def _stream(windows: np.ndarray, total: int):
    """Replay ``windows`` in CHUNK-sized bursts until ~``total`` served."""
    n = 0
    while n < total:
        for i in range(0, windows.shape[0], CHUNK):
            c = windows[i:i + CHUNK]
            yield c
            n += c.shape[0]
            if n >= total:
                return


def run(csv_rows: list) -> dict:
    mode = "smoke" if SMOKE else "full"
    print(f"\n== Serve: streaming anomaly scoring ({mode}) ==")
    serve_engine._SCORER_CACHE.clear()
    tmp = tempfile.mkdtemp(prefix="bench_serve_")
    cells, naives = [], []
    gate_ok = True

    for dataset, model in GRID:
        fed, ckpt, train_s, auc = _train_engine(tmp, dataset, model)
        windows = np.asarray(fed.test_x, np.float32)
        spec = get_model_spec(model, meta_for(fed))

        # ---- bucketed, double-buffered engine: one cell per bucket ------
        per_bucket_wps = {}
        for bucket in BUCKETS:
            eng = ServeEngine.from_checkpoint(ckpt, buckets=(bucket,))
            m0 = serve_engine.SERVE_STATS["misses"]
            eng.warmup()
            compiles = serve_engine.SERVE_STATS["misses"] - m0
            # bucket may be cached from an earlier engine: 0 or 1 misses,
            # never more
            assert compiles <= 1, (model, bucket, compiles)

            reports = []

            def timed(eng=eng, reports=reports):
                reports.append(
                    eng.score_stream(_stream(windows, STREAM_WINDOWS)))

            m1 = serve_engine.SERVE_STATS["misses"]
            timed()                                   # warm the whole path
            wall_s, walls = common.warm_min(timed, WARM_N)
            assert serve_engine.SERVE_STATS["misses"] == m1, (
                f"({model}, {bucket}): timed serving must never compile")

            best = min(reports[1:], key=lambda r: r.wall_s)
            # bitwise acceptance on the served stream (first replay pass)
            ref = np.asarray(jax.jit(
                lambda p, z: spec.predict_proba_routed(p, z, eng.route)
            )(eng.params, jnp.asarray(windows))[:, 1])
            got = best.scores[:windows.shape[0]]
            assert np.array_equal(got, ref[:got.shape[0]]), (
                f"({model}, {bucket}): served scores are not bitwise equal "
                "to the compiled predict_proba reference")

            cell = {
                "dataset": dataset, "model": model, "bucket": bucket,
                "route": eng.route,
                "windows_per_sec": best.windows_per_sec,
                "p50_ms": best.p50_s * 1e3,
                "p99_ms": best.p99_s * 1e3,
                "n_windows": best.n_windows,
                "n_batches": best.n_batches,
                "scorer_compiles": compiles,
                "train_s_unaudited": train_s,
                "auc": auc,
            }
            cells.append(cell)
            per_bucket_wps[bucket] = best.windows_per_sec
            print(f"  {dataset:9s} {model:4s} bucket={bucket:4d}: "
                  f"{best.windows_per_sec:10,.0f} win/s "
                  f"p50={cell['p50_ms']:.3f}ms p99={cell['p99_ms']:.3f}ms "
                  f"({compiles} compile)")
            csv_rows.append((f"serve/{dataset}/{model}/b{bucket}",
                             1e6 / best.windows_per_sec,
                             best.windows_per_sec))

        # ---- naive baseline: one blocking batch-1 dispatch per window ---
        eng = ServeEngine.from_checkpoint(ckpt, buckets=(BUCKETS[-1],))
        nx = windows[:NAIVE_WINDOWS]
        eng.score_naive(nx)                           # warm the b=1 program

        def naive(eng=eng, nx=nx):
            naive.last = eng.score_naive(nx)

        naive_wall, _ = common.warm_min(naive, max(WARM_N, 2))
        naive_wps = nx.shape[0] / naive_wall
        speedup = per_bucket_wps[BUCKETS[-1]] / naive_wps
        ok = speedup >= GATE_X
        gate_ok = gate_ok and ok
        naives.append({
            "dataset": dataset, "model": model,
            "naive_windows_per_sec": naive_wps,
            "naive_p50_ms": naive.last.p50_s * 1e3,
            "engine_windows_per_sec": per_bucket_wps[BUCKETS[-1]],
            "speedup_vs_naive": speedup,
            "gate_5x": ok,
        })
        print(f"  {dataset:9s} {model:4s} naive: {naive_wps:10,.0f} win/s "
              f"-> engine speedup {speedup:,.1f}x "
              f"{'OK' if ok else 'FAIL'}")

    report = {
        "mode": mode,
        "config": {"buckets": list(BUCKETS), "rounds": ROUNDS,
                   "stream_windows": STREAM_WINDOWS, "chunk": CHUNK,
                   "naive_windows": NAIVE_WINDOWS, "warm_n": WARM_N,
                   "backend": jax.default_backend(),
                   "n_devices": len(jax.devices())},
        "grid": cells,
        "naive_baseline": naives,
        "gate": {"required_speedup": GATE_X,
                 "all_models_pass": bool(gate_ok),
                 "gated": not SMOKE},
    }
    with open(OUT, "w") as f:
        json.dump(report, f, indent=1)
    print(f"  -> {os.path.abspath(OUT)}")

    common.record_bench("serve", [
        {"lane_key": f"{c['dataset']}/{c['model']}/b{c['bucket']}",
         "lane_params": {"dataset": c["dataset"], "model": c["model"],
                         "bucket": c["bucket"], "route": c["route"]},
         "metrics": {"windows_per_sec": (c["windows_per_sec"], 1),
                     "p50_ms": c["p50_ms"], "p99_ms": c["p99_ms"],
                     "auc": c["auc"]}}
        for c in cells
    ] + [
        {"lane_key": f"{n['dataset']}/{n['model']}/speedup",
         "lane_params": {"dataset": n["dataset"], "model": n["model"]},
         "metrics": {"speedup_vs_naive": (n["speedup_vs_naive"], 1)}}
        for n in naives
    ], mode=mode)
    return report


if __name__ == "__main__":
    report = run([])
    if report["gate"]["gated"] and not report["gate"]["all_models_pass"]:
        raise SystemExit(
            "serve gate failed: batched double-buffered serving did not "
            f"reach {GATE_X}x the naive per-window loop on every model")
