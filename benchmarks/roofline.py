"""Roofline analysis over the dry-run artifacts (deliverable g).

Reads ``benchmarks/artifacts/<arch>__<shape>__<mesh>[__tag].json`` (written
by ``repro.launch.dryrun``) and derives the three roofline terms per
(arch × shape × mesh):

    compute term    = HLO_FLOPs_per_device            / peak_FLOP/s
    memory term     = HLO_bytes_per_device            / HBM_bw
    collective term = collective_bytes_per_device     / link_bw

(cost_analysis and the parsed HLO are post-SPMD per-partition programs, so
per-device numbers divided by per-chip capability equal the prompt's
global/(chips × capability) form.)

Also: MODEL_FLOPS = 6·N·D (N = active params for MoE; D = tokens the step
actually processes, from the step metadata) and the usefulness ratio
MODEL_FLOPS / (HLO_FLOPs × devices) — <1 flags remat/redundant compute,
>1 flags FLOPs the 6ND model does not count (attention, routing).

Usage: PYTHONPATH=src python -m benchmarks.roofline [--csv out.csv] [--md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, NamedTuple, Optional


class Peaks(NamedTuple):
    """Per-chip peak capabilities the three roofline terms divide by."""

    flops: float      # dense bf16/fp32-accum FLOP/s
    hbm_bw: float     # bytes/s main-memory bandwidth
    link_bw: float    # bytes/s per inter-chip link (ICI / NVLink / socket)


# Datasheet-order numbers per backend; roofline terms are ratios, so ~10%
# spec-sheet slop never flips the dominant term.  Select with --backend,
# or override any single peak with --peak-flops/--peak-hbm-bw/--peak-link-bw.
BACKEND_PEAKS: Dict[str, Peaks] = {
    "tpu_v5e": Peaks(flops=197e12, hbm_bw=819e9, link_bw=50e9),
    "tpu_v4": Peaks(flops=275e12, hbm_bw=1228e9, link_bw=100e9),
    "gpu_a100": Peaks(flops=312e12, hbm_bw=2039e9, link_bw=300e9),
    # a big server CPU: ~32 AVX-512 cores, 8-channel DDR, one UPI link
    "cpu": Peaks(flops=2e12, hbm_bw=200e9, link_bw=20e9),
}
DEFAULT_BACKEND = "tpu_v5e"   # the assigned accelerator (mesh.py matches)

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "artifacts")


def load_artifacts(pattern: str = "*") -> List[dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(ARTIFACT_DIR, f"{pattern}.json"))):
        with open(p) as f:
            d = json.load(f)
        if isinstance(d, dict) and "arch" in d:  # skip fl_results.json etc.
            out.append(d)
    return out


def scan_product(a: dict) -> float:
    """Scan trip-count correction (EXPERIMENTS.md §Roofline).

    XLA's cost_analysis counts a while-loop (lax.scan) body ONCE — verified
    empirically: a 10-trip scanned matmul reports 10x fewer flops than its
    unrolled twin.  The stacks here are scanned over layers (× clients ×
    local-steps × grad-accum for FL train), so HLO-derived flops / bytes /
    collective-bytes must be multiplied by the known static trip product.
    Outside-scan work (embedding, logits, server update, the delta
    aggregation all-reduce) gets overcounted by the same factor — acceptable
    because the layer stack dominates all three terms for every assigned
    config; the approximation is flagged in the table.
    """
    meta = a.get("meta") or {}
    if "scan" in meta:
        return float(meta["scan"]["product"])
    # legacy artifacts: recompute from the config + step meta
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.configs.base import get_arch
    from repro.launch.steps import _scan_correction

    cfg = get_arch(a["arch"])
    if a["shape"].startswith("train"):
        plan = meta.get("plan", "client_serial")
        c = _scan_correction(
            cfg, "train",
            clients_scan=(1 if plan == "client_parallel"
                          else meta.get("clients_in_step", 2)),
            local_steps=1, grad_accum=meta.get("grad_accum", 1),
        )
    else:
        c = _scan_correction(cfg, a["shape"])
    return float(c["product"])


def analyse(a: dict, peaks: Optional[Peaks] = None) -> dict:
    peaks = peaks or BACKEND_PEAKS[DEFAULT_BACKEND]
    corr = scan_product(a)
    flops_dev = a["cost"]["flops"] * corr
    bytes_dev = a["cost"]["bytes_accessed"] * corr
    coll_dev = a["collectives"]["total"] * corr
    n_dev = a["devices"]

    t_compute = flops_dev / peaks.flops
    t_memory = bytes_dev / peaks.hbm_bw
    t_coll = coll_dev / peaks.link_bw
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)

    n_active = a.get("model_active_params") or a.get("model_params")
    tokens = (a.get("meta") or {}).get("tokens_per_step", 0)
    mult = 3.0 if a["shape"].startswith("train") else 1.0  # fwd+bwd vs fwd
    model_flops = 2.0 * mult * n_active * tokens
    total_hlo = flops_dev * n_dev
    ratio = model_flops / total_hlo if total_hlo else float("nan")

    # roofline fraction: useful model FLOPs per second achievable given the
    # dominant bottleneck (how far from pure-compute roofline this step sits)
    t_bound = max(terms.values())
    mfu_bound = (model_flops / n_dev / t_bound) / peaks.flops if t_bound else 0.0

    return {
        "arch": a["arch"], "shape": a["shape"], "mesh": a["mesh"],
        "tag": a.get("tag", ""),
        "plan": (a.get("meta") or {}).get("plan", a.get("step", "")),
        "t_compute_s": t_compute, "t_memory_s": t_memory, "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": model_flops, "hlo_flops_total": total_hlo,
        "hlo_flops_raw": a["cost"]["flops"],
        "scan_correction": corr,
        "useful_ratio": ratio,
        "mfu_bound": mfu_bound,
        "peak_gib": (a["memory"]["peak_bytes"] or 0) / 2**30,
        "compile_s": a.get("compile_s"),
        "coll_counts": a["collectives"].get("counts", {}),
    }


SUGGESTIONS = {
    "compute": "reduce redundant compute: loosen remat policy, cut grad_accum, "
               "or drop the client-scan multiplicity",
    "memory": "raise arithmetic intensity: fuse attention (flash kernel), "
              "larger microbatch per chip, bf16 accumulators",
    "collective": "re-shard to cut collective volume: FSDP prefetch overlap, "
                  "reduce-scatter instead of all-reduce, shard deltas before DP",
}


def to_markdown(rows: List[dict]) -> str:
    hdr = ("| arch | shape | mesh | plan | compute s | memory s | collective s |"
           " dominant | 6ND/HLO | MFU-bound | peak GiB |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in rows:
        body += (
            f"| {r['arch']} | {r['shape']} | {r['mesh']}{('/' + r['tag']) if r['tag'] else ''} "
            f"| {r['plan']} | {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['mfu_bound']*100:.1f}% "
            f"| {r['peak_gib']:.2f} |\n"
        )
    return hdr + body


def resolve_peaks(backend: str, peak_flops: Optional[float] = None,
                  peak_hbm_bw: Optional[float] = None,
                  peak_link_bw: Optional[float] = None) -> Peaks:
    """Backend-table peaks with per-term overrides (the --peak-* flags)."""
    base = BACKEND_PEAKS[backend]
    return Peaks(flops=peak_flops or base.flops,
                 hbm_bw=peak_hbm_bw or base.hbm_bw,
                 link_bw=peak_link_bw or base.link_bw)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pattern", default="*")
    ap.add_argument("--csv", default=None)
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--backend", choices=sorted(BACKEND_PEAKS),
                    default=DEFAULT_BACKEND,
                    help="peak table the roofline terms divide by")
    ap.add_argument("--peak-flops", type=float, default=None,
                    help="override peak FLOP/s per chip")
    ap.add_argument("--peak-hbm-bw", type=float, default=None,
                    help="override memory bandwidth (bytes/s per chip)")
    ap.add_argument("--peak-link-bw", type=float, default=None,
                    help="override inter-chip link bandwidth (bytes/s)")
    args = ap.parse_args()

    peaks = resolve_peaks(args.backend, args.peak_flops, args.peak_hbm_bw,
                          args.peak_link_bw)
    rows = [analyse(a, peaks) for a in load_artifacts(args.pattern)]
    if not rows:
        print("no artifacts found — run `python -m repro.launch.dryrun --all` first")
        return
    if args.md:
        print(to_markdown(rows))
    else:
        for r in rows:
            print(
                f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:6s} {r['tag']:14s}"
                f" C={r['t_compute_s']:.2e}s M={r['t_memory_s']:.2e}s"
                f" X={r['t_collective_s']:.2e}s dom={r['dominant']:10s}"
                f" 6ND/HLO={r['useful_ratio']:.2f} MFUb={r['mfu_bound']*100:5.1f}%"
            )
            print(f"{'':24s} -> {SUGGESTIONS[r['dominant']]}")
    if args.csv:
        import csv

        with open(args.csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=[k for k in rows[0] if k != "coll_counts"],
                               extrasaction="ignore")
            w.writeheader()
            w.writerows(rows)
        print(f"wrote {args.csv}")


if __name__ == "__main__":
    main()
