"""Model-diversity benchmark (ISSUE 4): the pluggable-detector engine.

A model × seed grid on both workload families, written to
``BENCH_models.json`` at the repo root:

* ``unsw`` — the paper's tabular flow features (flattened MLP);
* ``road_raw`` — raw CAN windows (``feature_shape=(window, signals)``):
  the flattened MLP baseline vs the window-native detectors
  (``models/detectors.py``: 1-D CNN, RG-LRU recurrent, and — ISSUE 10 —
  the kernel-routed sequence substrate: Mamba-2 SSD ``ssm`` and causal
  self-attention ``attn``).

Hard assertions:

* **one compile per model static** — every (dataset, model) cell's seed
  batch is one ``_get_runner`` miss (RUNNER_STATS), rerunning a cell is
  zero misses: ``FLConfig.model`` rides the statics key exactly like
  ``selection``/``plan``;
* **window-native wins on windows** — on ``road_raw`` the best
  window-native detector's mean AUC must match or beat the flattened
  MLP's (the structure the MLP destroys is the ROAD signal; gated in full
  mode, recorded always);
* **sequence beats CNN** (ISSUE 10) — at least one sequence detector
  (``ssm``/``attn``) must beat the CNN's mean AUC on ``road_raw`` under
  the identical FL protocol (gated in full mode, recorded always).

Timing protocol (repo memory: very noisy wall clocks): per-cell walls are
warm min-of-N via ``benchmarks/common.warm_min`` — compile happens before
any timed call, and cold compile seconds are recorded separately,
unaudited.

``REPRO_MODELS_SMOKE=1`` shrinks the grid and skips the AUC gate
(correctness/compile-count assertions stay on).
"""
from __future__ import annotations

import dataclasses
import json
import os

import jax
import numpy as np

from repro.configs.base import FLConfig
from repro.data.synthetic import make_federated
from repro.train import fl_driver

from benchmarks import common

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_models.json")

# Sizing note: the window-native detectors cost real CPU (conv /
# associative-scan over 64-step windows, vmapped over clients); the grid is
# sized so the full run stays in CPU-minutes while leaving enough total
# local steps (rounds × local_epochs) for the architectures to separate.
SMOKE = os.environ.get("REPRO_MODELS_SMOKE", "0") == "1"
N_CLIENTS = 8 if SMOKE else 12
N_SAMPLES = 1_000 if SMOKE else 2_400
ROUNDS = 8 if SMOKE else 40
SEEDS = (0, 1) if SMOKE else (0, 1, 2)
EVAL_EVERY = 4 if SMOKE else 10
WARM_N = 1 if SMOKE else 2

# (dataset, model) grid: the MLP baseline runs on both workloads, the
# window-native detectors only on raw windows (they reject tabular meta).
# ISSUE 10 grows the model axis with the sequence substrate: the Mamba-2
# SSD detector and the causal-attention detector, both kernel-routed.
GRID = (
    ("unsw", "mlp"),
    ("road_raw", "mlp"),
    ("road_raw", "cnn"),
    ("road_raw", "rglru"),
    ("road_raw", "ssm"),
    ("road_raw", "attn"),
)

# the sequence-substrate gate (ISSUE 10): at least one sequence detector
# must beat the PR 4 CNN on road_raw under the identical FL protocol
SEQUENCE_MODELS = ("ssm", "attn")


def _bench_fl(**kw) -> FLConfig:
    return FLConfig(
        n_clients=N_CLIENTS, clients_per_round=4, rounds=ROUNDS,
        local_epochs=3, local_batch=32, local_lr=0.1,
        dp_enabled=True, dp_mode="clipped", dp_epsilon=1000.0, dp_clip=1.0,
        fault_tolerance=True, failure_prob=0.05, **kw)


def run(csv_rows: list) -> dict:
    mode = "smoke" if SMOKE else "full"
    print(f"\n== Models: pluggable-detector grid ({mode}) ==")
    feds = {ds: make_federated(0, ds, n_samples=N_SAMPLES,
                               n_clients=N_CLIENTS)
            for ds in {ds for ds, _ in GRID}}

    fl_driver._RUNNER_CACHE.clear()
    cells = []
    for ds, model in GRID:
        cfg = _bench_fl(model=model)
        fed = feds[ds]
        m0 = fl_driver.RUNNER_STATS["misses"]
        res, cold_s = common.timed_call(
            lambda fed=fed, cfg=cfg: fl_driver.run_fl_batch(
                fed, cfg, "proposed", seeds=SEEDS, rounds=ROUNDS,
                eval_every=EVAL_EVERY),
            label="models.cold")
        misses = fl_driver.RUNNER_STATS["misses"] - m0
        assert misses == 1, (
            f"({ds}, {model}): expected exactly one compile for the seed "
            f"batch, got {misses}")

        def warm_call(fed=fed, cfg=cfg):
            fl_driver.run_fl_batch(fed, cfg, "proposed", seeds=SEEDS,
                                   rounds=ROUNDS, eval_every=EVAL_EVERY)

        m1 = fl_driver.RUNNER_STATS["misses"]
        warm_s, walls = common.warm_min(warm_call, WARM_N)
        assert fl_driver.RUNNER_STATS["misses"] == m1, (
            f"({ds}, {model}): warm reruns must be pure cache hits")

        cell = {
            "dataset": ds,
            "model": model,
            "auc_mean": float(np.mean([r.auc for r in res])),
            "auc_per_seed": [float(r.auc) for r in res],
            "acc_mean": float(np.mean([r.accuracy for r in res])),
            "eps_spent": float(res[0].eps_spent),
            "cold_s_unaudited": cold_s,
            "warm_execute_s_min": warm_s,
            "warm_execute_s_all": walls,
            "runner_compiles": misses,
        }
        cells.append(cell)
        print(f"  {ds:9s} {model:6s} auc={cell['auc_mean']:.3f} "
              f"acc={cell['acc_mean']:.3f} warm={warm_s:6.2f}s "
              f"(cold {cold_s:6.2f}s, 1 compile)")
        csv_rows.append((f"models/{ds}/{model}", warm_s * 1e6,
                         cell["auc_mean"]))

    road = {c["model"]: c["auc_mean"] for c in cells
            if c["dataset"] == "road_raw"}
    best_window = max(road[m] for m in ("cnn", "rglru"))
    auc_gate = bool(best_window >= road["mlp"] - 0.01)
    best_seq_model = max(SEQUENCE_MODELS, key=lambda m: road[m])
    best_sequence = road[best_seq_model]
    seq_gate = bool(best_sequence > road["cnn"])

    report = {
        "mode": mode,
        "config": {"n_clients": N_CLIENTS, "rounds": ROUNDS,
                   "seeds": list(SEEDS), "n_samples": N_SAMPLES,
                   "warm_n": WARM_N,
                   "backend": jax.default_backend(),
                   "n_devices": len(jax.devices())},
        "grid": cells,
        "road_raw_auc": {"mlp_flattened": road["mlp"],
                         "best_window_native": best_window,
                         "window_native_matches_or_beats_mlp": auc_gate,
                         "cnn": road["cnn"],
                         "best_sequence": best_sequence,
                         "best_sequence_model": best_seq_model,
                         "sequence_beats_cnn": seq_gate,
                         "gated": not SMOKE},
    }
    with open(OUT, "w") as f:
        json.dump(report, f, indent=1)

    common.record_bench("models", [
        {"lane_key": f"{c['dataset']}/{c['model']}",
         "statics_key": common.statics_key(_bench_fl(model=c["model"])),
         "wall_cold_s": c["cold_s_unaudited"],
         "warm_walls": c["warm_execute_s_all"],
         "lane_params": {"dataset": c["dataset"], "model": c["model"],
                         "rounds": ROUNDS, "seeds": list(SEEDS)},
         "metrics": {"auc_mean": (c["auc_mean"], 1),
                     "acc_mean": c["acc_mean"],
                     "runner_compiles": float(c["runner_compiles"])}}
        for c in cells
    ], mode=mode)

    print(f"  road_raw: best window-native auc {best_window:.3f} vs "
          f"flattened mlp {road['mlp']:.3f} -> "
          f"{'OK' if auc_gate else 'FAIL'}"
          f"{' (not gated in smoke)' if SMOKE else ''}")
    print(f"  road_raw: best sequence auc {best_sequence:.3f} "
          f"({best_seq_model}) vs cnn {road['cnn']:.3f} -> "
          f"{'OK' if seq_gate else 'FAIL'}"
          f"{' (not gated in smoke)' if SMOKE else ''}")
    print(f"  -> {os.path.abspath(OUT)}")
    return report


if __name__ == "__main__":
    report = run([])
    if report["road_raw_auc"]["gated"] and \
            not report["road_raw_auc"]["window_native_matches_or_beats_mlp"]:
        raise SystemExit(
            "models gate failed: no window-native detector matched the "
            "flattened MLP's AUC on road_raw")
    if report["road_raw_auc"]["gated"] and \
            not report["road_raw_auc"]["sequence_beats_cnn"]:
        raise SystemExit(
            "models gate failed: no sequence detector (ssm/attn) beat the "
            "CNN's AUC on road_raw")
