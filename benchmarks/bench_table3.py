"""Table III — Mann-Whitney U significance tests.

Paper: proposed vs ACFL / FedL2P on both datasets, AUC-ROC distributions,
all p < 0.05.  On the synthetic stand-ins the proposed method's advantage
expresses in ACCURACY (the corrupted-client exclusion moves the decision
boundary, not the ranking), so we run the test on both metrics over the
converged-half round-wise samples of every seed and report both:
accuracy significance reproduces the paper's conclusion; AUC does not
separate on the stand-ins (flagged honestly in EXPERIMENTS.md §Table-III).
The repeated trials the U test needs are cheap: every cell's seeds run as
one compiled sweep lane batch (methods differ in STATIC selection strategy,
so each method compiles its own program; within a method the seeds — and
any runtime grid — share it.  EXPERIMENTS.md §Sweeps).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import run_grid
from repro.stats import mannwhitney_greater

DATASETS = ("unsw", "road")
BASELINES = ("acfl", "fedl2p")


def _samples(rows, method, dataset, field):
    """Per-seed FINAL metrics (the paper's '10 repeated trials' design).
    Round-wise histories would be wrong for FedL2P, whose reported metric
    comes from the post-training personalisation pass."""
    key = {"acc": "accuracy", "auc": "auc"}[field]
    return np.asarray([
        r[key] for r in rows
        if r["method"] == method and r["dataset"] == dataset
    ])


def run(csv_rows: list):
    rows = run_grid(("proposed",) + BASELINES, DATASETS)
    print("\n== Table III: Mann-Whitney U (proposed vs baselines) ==")
    print(f"{'dataset':8s} {'comparison':22s} {'metric':6s} {'U':>9s} "
          f"{'p-value':>12s} {'sig?':>6s}")
    acc_all_sig = True
    for ds in DATASETS:
        for metric in ("acc", "auc"):
            a = _samples(rows, "proposed", ds, metric)
            for b_name in BASELINES:
                b = _samples(rows, b_name, ds, metric)
                u, p, sig = mannwhitney_greater(a, b)
                if metric == "acc":
                    acc_all_sig &= sig
                print(f"{ds:8s} proposed vs {b_name:10s} {metric:6s} {u:9.1f} "
                      f"{p:12.3e} {str(sig):>6s}")
                csv_rows.append((f"table3/{ds}/proposed_vs_{b_name}/{metric}_p",
                                 0.0, p))
    print(f"claim (on accuracy): all comparisons significant -> {acc_all_sig}")
    print("note: AUC does not separate on the synthetic stand-ins; the "
          "accuracy gap (+5..15pts) carries the significance (EXPERIMENTS.md).")
    return acc_all_sig


if __name__ == "__main__":
    run([])
