"""Reproduce the §Perf hillclimb iterations (EXPERIMENTS.md) as tagged
dry-run artifacts.  Each variant re-lowers + compiles the pair and prints
the corrected roofline terms next to its baseline.

Usage:
  PYTHONPATH=src:. python -m benchmarks.hillclimb [--pair A|B|C|all]

(Each compile is ~10-90s on the CPU host; ~15 compiles for --pair all.)
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse


def _terms(r):
    prod = (r["meta"].get("scan") or {}).get("product", 1.0)
    return (
        r["cost"]["flops"] * prod / 197e12,
        r["cost"]["bytes_accessed"] * prod / 819e9,
        r["collectives"]["total"] * prod / 50e9,
        (r["memory"]["temp_bytes"] or 0) / 2**30,
    )


def show(tag, r):
    c, m, x, t = _terms(r)
    print(f"  {tag:28s} C={c:8.2f}s M={m:8.2f}s X={x:8.2f}s temp={t:7.2f}GiB")


def pair_a():
    """mistral-123B × train_4k — the FL round at max dense scale."""
    from repro.launch.dryrun import run_one
    from repro.models.sharding import make_rules

    print("== Pair A: mistral_large_123b x train_4k ==")
    show("baseline ga16", run_one("mistral_large_123b", "train_4k", "single",
                                  tag="rebase"))
    seqpar = dict(make_rules("client_serial", False))
    seqpar["act_seq"] = ("model",)
    show("A1 seq-parallel", run_one("mistral_large_123b", "train_4k", "single",
                                    step_kw={"rules_override": seqpar},
                                    tag="seqpar"))
    for ga in (8, 4):
        show(f"A2 ga={ga}", run_one("mistral_large_123b", "train_4k", "single",
                                    step_kw={"grad_accum": ga}, tag=f"ga{ga}"))
    show("A3 ga8+dots", run_one("mistral_large_123b", "train_4k", "single",
                                step_kw={"grad_accum": 8, "remat": "dots"},
                                tag="ga8dots"))
    show("A4 remat_group=8", run_one("mistral_large_123b", "train_4k", "single",
                                     step_kw={"remat_group": 8}, tag="grp8"))
    print("  A6 (S² score buffers; flash-kernel fit argument): see "
          "EXPERIMENTS.md §Perf — probed via seq sweeps.")


def pair_b():
    """mamba2-130m × decode_32k — most collective-bound."""
    from repro.launch.dryrun import run_one

    print("== Pair B: mamba2_130m x decode_32k ==")
    show("baseline (heads)", run_one("mamba2_130m", "decode_32k", "single",
                                     step_kw={"ssm_shard": "heads"},
                                     tag="heads"))
    show("B1 ssm_shard=state", run_one("mamba2_130m", "decode_32k", "single",
                                       step_kw={"ssm_shard": "state"},
                                       tag="ssmstate"))
    rules = {"embed": None, "mlp": None, "heads": None, "kv": None,
             "vocab": None, "experts": None, "layers": None,
             "act_batch": ("data",), "act_seq": None, "ssm_state": None}
    show("B2 replicated weights", run_one(
        "mamba2_130m", "decode_32k", "single",
        step_kw={"ssm_shard": "state", "rules_override": rules},
        tag="replicated"))
    show("B3 conv replicated", run_one(
        "mamba2_130m", "decode_32k", "single",
        step_kw={"ssm_shard": "state_convrep"}, tag="stateconvrep"))


def pair_c():
    """llama4-400B × train_4k — worst roofline fraction."""
    from repro.launch.dryrun import run_one
    from repro.models import transformer as T

    print("== Pair C: llama4_maverick_400b x train_4k ==")
    show("baseline einsum MoE", run_one("llama4_maverick_400b", "train_4k",
                                        "single", tag="rebase"))
    T.MOE_IMPL[0] = "scatter"
    try:
        show("C1 scatter dispatch", run_one("llama4_maverick_400b", "train_4k",
                                            "single", tag="scatter"))
    finally:
        T.MOE_IMPL[0] = "einsum"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", choices=["A", "B", "C", "all"], default="all")
    args = ap.parse_args()
    if args.pair in ("A", "all"):
        pair_a()
    if args.pair in ("B", "all"):
        pair_b()
    if args.pair in ("C", "all"):
        pair_c()


if __name__ == "__main__":
    main()
