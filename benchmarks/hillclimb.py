"""Reproduce the §Perf hillclimb iterations (EXPERIMENTS.md) as tagged
dry-run artifacts.  Each variant re-lowers + compiles the pair and prints
the corrected roofline terms next to its baseline.

Tuning history lives in the EXPERIMENT STORE (docs/DESIGN.md §8), not in
ad-hoc JSON: every variant's roofline terms are written through
``common.record_bench("hillclimb", ...)`` — one lane per (pair, variant),
terms recorded lower-is-better so ``tools/bench_regress.py`` gates a
variant that regresses against its own stored history, and
``tools/metric_trajectory.py --bench hillclimb --metric roofline_s``
prints the tuning trajectory across ENGINE_REV.  (``run_one`` still drops
its per-variant dry-run JSON under benchmarks/artifacts/ — that is the
full lowered-program forensics, not the comparison state.)

Usage:
  PYTHONPATH=src:. python -m benchmarks.hillclimb [--pair A|B|C|all]

(Each compile is ~10-90s on the CPU host; ~15 compiles for --pair all.)
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse

# store cells accumulated by show(), written through once per invocation
_CELLS = []


def _terms(r):
    prod = (r["meta"].get("scan") or {}).get("product", 1.0)
    return (
        r["cost"]["flops"] * prod / 197e12,
        r["cost"]["bytes_accessed"] * prod / 819e9,
        r["collectives"]["total"] * prod / 50e9,
        (r["memory"]["temp_bytes"] or 0) / 2**30,
    )


def show(tag, r, pair=""):
    c, m, x, t = _terms(r)
    print(f"  {tag:28s} C={c:8.2f}s M={m:8.2f}s X={x:8.2f}s temp={t:7.2f}GiB")
    _CELLS.append({
        "lane_key": f"{pair}:{tag}" if pair else tag,
        "statics_key": f"{r['arch']}__{r['shape']}__{r['mesh']}",
        "lane_params": {"arch": r["arch"], "shape": r["shape"],
                        "mesh": r["mesh"], "tag": r["tag"]},
        # lower-is-better directions: the regression gate flags a variant
        # whose roofline terms grow against its own stored history
        "metrics": {"roofline_s": (c + max(m, x), -1),
                    "compute_s": (c, -1), "memory_s": (m, -1),
                    "collective_s": (x, -1), "temp_gib": (t, -1),
                    "compile_s": r["compile_s"]},
    })


def pair_a():
    """mistral-123B × train_4k — the FL round at max dense scale."""
    from repro.launch.dryrun import run_one
    from repro.models.sharding import make_rules

    print("== Pair A: mistral_large_123b x train_4k ==")
    show("baseline ga16", run_one("mistral_large_123b", "train_4k", "single",
                                  tag="rebase"), pair="A")
    seqpar = dict(make_rules("client_serial", False))
    seqpar["act_seq"] = ("model",)
    show("A1 seq-parallel", run_one("mistral_large_123b", "train_4k", "single",
                                    step_kw={"rules_override": seqpar},
                                    tag="seqpar"), pair="A")
    for ga in (8, 4):
        show(f"A2 ga={ga}", run_one("mistral_large_123b", "train_4k", "single",
                                    step_kw={"grad_accum": ga}, tag=f"ga{ga}"),
             pair="A")
    show("A3 ga8+dots", run_one("mistral_large_123b", "train_4k", "single",
                                step_kw={"grad_accum": 8, "remat": "dots"},
                                tag="ga8dots"), pair="A")
    show("A4 remat_group=8", run_one("mistral_large_123b", "train_4k", "single",
                                     step_kw={"remat_group": 8}, tag="grp8"),
         pair="A")
    print("  A6 (S² score buffers; flash-kernel fit argument): see "
          "EXPERIMENTS.md §Perf — probed via seq sweeps.")


def pair_b():
    """mamba2-130m × decode_32k — most collective-bound."""
    from repro.launch.dryrun import run_one

    print("== Pair B: mamba2_130m x decode_32k ==")
    show("baseline (heads)", run_one("mamba2_130m", "decode_32k", "single",
                                     step_kw={"ssm_shard": "heads"},
                                     tag="heads"), pair="B")
    show("B1 ssm_shard=state", run_one("mamba2_130m", "decode_32k", "single",
                                       step_kw={"ssm_shard": "state"},
                                       tag="ssmstate"), pair="B")
    rules = {"embed": None, "mlp": None, "heads": None, "kv": None,
             "vocab": None, "experts": None, "layers": None,
             "act_batch": ("data",), "act_seq": None, "ssm_state": None}
    show("B2 replicated weights", run_one(
        "mamba2_130m", "decode_32k", "single",
        step_kw={"ssm_shard": "state", "rules_override": rules},
        tag="replicated"), pair="B")
    show("B3 conv replicated", run_one(
        "mamba2_130m", "decode_32k", "single",
        step_kw={"ssm_shard": "state_convrep"}, tag="stateconvrep"),
        pair="B")


def pair_c():
    """llama4-400B × train_4k — worst roofline fraction."""
    from repro.launch.dryrun import run_one
    from repro.models import transformer as T

    print("== Pair C: llama4_maverick_400b x train_4k ==")
    show("baseline einsum MoE", run_one("llama4_maverick_400b", "train_4k",
                                        "single", tag="rebase"), pair="C")
    T.MOE_IMPL[0] = "scatter"
    try:
        show("C1 scatter dispatch", run_one("llama4_maverick_400b", "train_4k",
                                            "single", tag="scatter"),
             pair="C")
    finally:
        T.MOE_IMPL[0] = "einsum"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", choices=["A", "B", "C", "all"], default="all")
    args = ap.parse_args()
    if args.pair in ("A", "all"):
        pair_a()
    if args.pair in ("B", "all"):
        pair_b()
    if args.pair in ("C", "all"):
        pair_c()
    if _CELLS:
        # imported late: the XLA_FLAGS env tweak at module top must land
        # before anything pulls in jax
        from benchmarks import common
        from repro.obs.store import ExperimentStore, default_store_path

        common.record_bench(
            "hillclimb", _CELLS, mode="full",
            note=f"pair={args.pair} ({len(_CELLS)} variants)")
        print()
        print(ExperimentStore(default_store_path())
              .trajectory_report("hillclimb", "roofline_s"))


if __name__ == "__main__":
    main()
