"""Shared experiment harness for the paper-table benchmarks.

Runs (method × dataset × seed) FL trainings once and caches RunResults in
``benchmarks/artifacts/fl_results.json`` so Tables I/II/III and Fig. 3 reuse
the same trials (the paper also reports means over 10 repeated trials).

All uncached cells of a (method, dataset) GRID run as ONE compiled program
via ``run_fl_sweep`` (the seed×config lane engine, EXPERIMENTS.md §Sweeps):
an ε column (Fig. 3) or a failure-probability ablation (Table II) is a
single compile + a single batched device program, not one per grid point.
Single cells go through the same path (``run_sweep_cells`` with one cell).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.configs.base import FLConfig, fl_static
from repro.data.synthetic import make_federated
from repro.obs import trace as obs_trace
from repro.obs.store import default_store
from repro.train.fl_driver import RunResult, run_fl_sweep

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "artifacts")
CACHE = os.path.join(ARTIFACT_DIR, "fl_results.json")

# Scaled-down defaults so the whole suite runs in CPU-minutes; the paper's
# full setting (40 clients, 200 rounds, 10 trials) is reachable via env var
# REPRO_FULL=1.
FULL = os.environ.get("REPRO_FULL", "0") == "1"
N_CLIENTS = 40 if FULL else 24
ROUNDS = 200 if FULL else 50
N_SEEDS = 10 if FULL else 5
N_SAMPLES = {"unsw": 20_000 if FULL else 8_000, "road": 5_000 if FULL else 2_400,
             "road_raw": 5_000 if FULL else 2_400}


def base_fl(n_clients: int = N_CLIENTS, **kw) -> FLConfig:
    cfg = FLConfig(
        n_clients=n_clients,
        clients_per_round=max(4, n_clients // 5),
        rounds=ROUNDS,
        local_epochs=5,
        local_batch=32,
        local_lr=0.08,
        dp_enabled=True,
        dp_mode="clipped",
        # per-round budget in the regime where training still learns (see
        # EXPERIMENTS.md: the paper's eps∈[0.1,10] labels are only consistent
        # with a much weaker mechanism); composed eps reported via RDP.
        dp_epsilon=1000.0,
        dp_delta=1e-5,
        dp_clip=1.0,
        fault_tolerance=True,
        failure_prob=0.05,
    )
    return dataclasses.replace(cfg, **kw) if kw else cfg


# Cache-key version: bump when the engine's stochastic process changes so a
# cell can never silently mix trials from different engines (the scan/vmap
# engine replaced the legacy loop's host-NumPy batch stream in PR 1;
# "sweep2": runtime FLParams — the DP noise scale is now derived from
# traced f32 scalars on device instead of a host f64 constant; "privacy3":
# road_like was vectorised, changing its RNG draw order — road federations
# differ sample-for-sample from the loop generator's; "models4": the two
# ISSUE-4 bugfixes change trajectories — adaptive-K no longer shrinks on
# round 1 (every adaptive_k cell's selection stream moves) and scheduled
# runs account ε at the realised ceil(k_eff)/n cohort fraction.  The
# ModelSpec refactor itself is bitwise-neutral for mlp lanes
# (tests/test_models.py).
ENGINE_REV = "models4"


def wall_min(fn: Callable[[], object], n: int, label: str = "warm",
             ) -> Tuple[float, List[float], object]:
    """(min, all, last_result) wall seconds of ``n`` calls of an
    already-compiled ``fn`` — the ONLY timing protocol acceptance gates
    may use on this container (very noisy wall clocks: a gate must never
    read a single cold run).  Compile/warm ``fn`` once before calling
    this.  Each repetition opens a host span ``bench.<label>`` (no-op
    while the tracer is off), so ``--profile`` / ``REPRO_TRACE`` runs
    show every timed call on the timeline."""
    walls, result = [], None
    for i in range(n):
        with obs_trace.span(f"bench.{label}", rep=i, n=n):
            t0 = time.time()
            result = fn()
            walls.append(time.time() - t0)
    return min(walls), walls, result


def warm_min(fn: Callable[[], object], n: int) -> Tuple[float, List[float]]:
    """Legacy two-tuple view of :func:`wall_min` (the benches' historical
    signature)."""
    t_min, walls, _ = wall_min(fn, n)
    return t_min, walls


def timed_call(fn: Callable[[], object], label: str = "cold",
               ) -> Tuple[object, float]:
    """(result, wall seconds) of one call under a ``bench.<label>`` span —
    the cold/compile timing counterpart of :func:`wall_min`."""
    with obs_trace.span(f"bench.{label}"):
        t0 = time.time()
        result = fn()
        wall = time.time() - t0
    return result, wall


def statics_key(fl: FLConfig) -> str:
    """12-hex fingerprint of the config's STATIC fields — the compiled
    program family a store lane compares against (two cells with equal
    ``statics_key`` + ENGINE_REV ran the same lowered program shape)."""
    return hashlib.md5(repr(fl_static(fl)).encode()).hexdigest()[:12]


def record_bench(bench: str, cells: Sequence[Dict[str, Any]],
                 mode: str = "full", note: str = "") -> Optional[int]:
    """Write one bench invocation through to the experiment store
    (docs/DESIGN.md §8) while the bench still emits its legacy
    ``BENCH_*.json``.  Each cell dict: ``lane_key`` (required) plus any of
    ``statics_key``, ``wall_cold_s``, ``wall_warm_s``, ``warm_walls``,
    ``lane_params``, ``metrics`` (name → value or (value, ±1) for gated).
    Returns the store run_id, or None when the store is unavailable (a
    bench never dies on telemetry)."""
    try:
        import jax
        backend = jax.default_backend()
    except Exception:
        backend = "unknown"
    try:
        store = default_store()
        run_id = store.begin_run(engine_rev=ENGINE_REV, backend=backend,
                                 mode=mode, note=note)
        for cell in cells:
            cell = dict(cell)
            store.record_cell(run_id, bench, cell.pop("lane_key"), **cell)
        obs_trace.event("store.record_bench", bench=bench,
                        run_id=run_id, n_cells=len(cells))
        return run_id
    except Exception as e:  # pragma: no cover - defensive
        print(f"[obs] store write failed for {bench}: {e}")
        return None


def _key(method, dataset, seed, tag):
    return f"{method}|{dataset}|{seed}|{tag}|{ENGINE_REV}"


def _load() -> Dict[str, dict]:
    if os.path.exists(CACHE):
        with open(CACHE) as f:
            return json.load(f)
    return {}


def _save(cache: Dict[str, dict]):
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    with open(CACHE, "w") as f:
        json.dump(cache, f, indent=1)


_FEDS: Dict[str, object] = {}


def get_fed(dataset: str, seed: int = 0):
    k = f"{dataset}|{seed}"
    if k not in _FEDS:
        _FEDS[k] = make_federated(seed, dataset, n_samples=N_SAMPLES[dataset],
                                  n_clients=N_CLIENTS, alpha=0.2,
                                  label_noise_frac=0.3, label_noise_rate=0.5)
    return _FEDS[k]


def run_sweep_cells(method: str, dataset: str,
                    cells: Sequence[Tuple[str, FLConfig]],
                    seeds: Sequence[int],
                    rounds: Optional[int] = None) -> Dict[str, List[dict]]:
    """A whole (method, dataset) GRID — ``cells`` is a list of
    ``(tag, FLConfig)`` differing only in runtime knobs — through the sweep
    engine: every uncached cell × seed lane runs in ONE compiled program
    (one ``_get_runner`` miss for the grid, see docs/ARCHITECTURE.md).

    Returns ``{tag: [result dict per seed]}``.  Cache granularity stays
    (method, dataset, seed, tag); a cell re-runs all its seeds when any one
    is missing (the lane is marginal cost next to a partial-cache dance).
    """
    cache = _load()
    seeds = [int(s) for s in seeds]
    missing = [(tag, cfg) for tag, cfg in cells
               if any(_key(method, dataset, s, tag) not in cache
                      for s in seeds)]
    if missing:
        fed = get_fed(dataset, seed=0)  # same federation across seeds; seed varies FL
        grid = run_fl_sweep(fed, missing[0][1], [cfg for _, cfg in missing],
                            seeds=seeds, method=method,
                            rounds=rounds or ROUNDS, dataset=dataset)
        for (tag, _), row in zip(missing, grid):
            for res in row:
                cache[_key(method, dataset, res.seed, tag)] = dataclasses.asdict(res)
        _save(cache)
    return {tag: [cache[_key(method, dataset, s, tag)] for s in seeds]
            for tag, _ in cells}


def run_cell(method: str, dataset: str, seeds: Sequence[int],
             fl: Optional[FLConfig] = None, tag: str = "default",
             rounds: Optional[int] = None) -> List[dict]:
    """All seeds of one (method, dataset) cell — a sweep of one config."""
    return run_sweep_cells(method, dataset, [(tag, fl or base_fl())], seeds,
                           rounds=rounds)[tag]


def run_cached(method: str, dataset: str, seed: int, fl: Optional[FLConfig] = None,
               tag: str = "default", rounds: Optional[int] = None) -> dict:
    return run_cell(method, dataset, [seed], fl=fl, tag=tag, rounds=rounds)[0]


def run_grid(methods: Sequence[str], datasets: Sequence[str],
             seeds: Sequence[int] = None, fl: Optional[FLConfig] = None,
             tag: str = "default") -> List[dict]:
    seeds = seeds if seeds is not None else list(range(N_SEEDS))
    out = []
    for ds in datasets:
        for m in methods:
            out.extend(run_cell(m, ds, seeds, fl=fl, tag=tag))
    return out


def mean_of(rows: List[dict], method: str, dataset: str, field: str) -> float:
    vals = [r[field] for r in rows if r["method"] == method and r["dataset"] == dataset]
    return sum(vals) / max(len(vals), 1)
