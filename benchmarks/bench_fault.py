"""Fault-subsystem benchmark (ISSUE 5): the compiled failure frontier.

Sections, written to ``BENCH_fault.json`` at the repo root:

* ``frontier`` — the accuracy-vs-failure-rate frontier: every failure
  process (iid / markov / weibull / straggler) × ≥2 rates × ≥2 seeds, with
  the selection coupling on (``fault_util_w``), all lanes in ONE compiled
  program (``fault_process``/``failure_prob`` are runtime FLParams lanes —
  the process code sweeps like ``dp_sched``).  Hard assertion: exactly one
  ``_get_runner`` miss for the whole grid.  Warm walls are min-of-N
  executes (repo timing protocol — never a single cold run).
* ``coupling_gate`` — the selection×fault interplay, gated by the same
  Mann-Whitney helper Table III uses (``repro/stats.py``): under BURSTY
  (Markov) outages, lanes with the reliability coupling on
  (``fault_util_w > 0``) route selection around clients observed failing
  — their outages persist, so avoidance pays — and accumulate
  significantly LESS simulated time (failed selections cost recovery /
  redo) than uncoupled lanes.  The two arms are runtime lanes of ONE
  program; measured 8/8 seeds positive, p≈3.5e-3 on the bench container.
* ``ft_ablation`` — with vs without fault tolerance at the highest rate
  (the paper's §IV "robustness" claim), recorded UNGATED: on the
  synthetic stand-ins mean aggregation over the surviving complete
  updates is already robust, so the FT accuracy benefit does not
  separate statistically (the honest-caveat pattern of Table III's AUC —
  see EXPERIMENTS.md §Fault-frontier).  The static with/without-FT split
  is asserted to be exactly one extra compile.
* always-on correctness: straggler lanes record zero failures but a
  longer simulated wall; killed-process lanes' observed marginal failure
  rate tracks the ``failure_prob`` lane.

``REPRO_FAULT_SMOKE=1`` shrinks the grid and skips the significance gate's
exit code — the compile-count and process-semantics assertions stay on.
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from repro.configs.base import FLConfig
from repro.data.synthetic import make_federated
from repro.fault import PROCESSES, process_code
from repro.stats import mannwhitney_greater
from repro.train import fl_driver

from benchmarks import common

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_fault.json")

SMOKE = os.environ.get("REPRO_FAULT_SMOKE", "0") == "1"
N_CLIENTS = 8 if SMOKE else 24
N_SAMPLES = 1_200 if SMOKE else 6_000
ROUNDS = 10 if SMOKE else 50
SEEDS = (0, 1) if SMOKE else (0, 1, 2, 3)
RATES = (0.0, 0.3) if SMOKE else (0.0, 0.2, 0.45)
EVAL_EVERY = 5 if SMOKE else 10
WARM_N = 2 if SMOKE else 3
FAULT_W = 1.0          # selection coupling ON across the frontier
KILLING = ("iid", "markov", "weibull")   # processes FT can defend against
# coupling gate: bursty outages where routing around observed failures pays
GATE_SEEDS = (0, 1) if SMOKE else tuple(range(8))
GATE_ROUNDS = 10 if SMOKE else 40
GATE_RATE = 0.3 if SMOKE else 0.45
GATE_BURST = 8.0
GATE_W = 5.0


def _bench_config(**kw) -> FLConfig:
    return FLConfig(
        n_clients=N_CLIENTS, clients_per_round=4, rounds=ROUNDS,
        local_epochs=5, local_batch=32, local_lr=0.08,
        dp_enabled=True, dp_mode="clipped", dp_epsilon=1000.0, dp_clip=1.0,
        fault_tolerance=True, failure_prob=0.05, **kw)


def _cells(rates):
    return [{"fault_process": process_code(p), "failure_prob": r,
             "fault_util_w": FAULT_W}
            for p in PROCESSES for r in rates]


def run(csv_rows: list) -> dict:
    mode = "smoke" if SMOKE else "full"
    print(f"\n== Fault: failure-process frontier + robustness gate ({mode}) ==")
    fed = make_federated(0, "unsw", n_samples=N_SAMPLES, n_clients=N_CLIENTS)
    fl = _bench_config()
    cells = _cells(RATES)

    # ---- frontier: every (process × rate) as runtime lanes, ONE compile ----
    fl_driver._RUNNER_CACHE.clear()
    m0 = fl_driver.RUNNER_STATS["misses"]
    sweep, t_cold = common.timed_call(
        lambda: fl_driver.run_fl_sweep(fed, fl, cells, seeds=SEEDS,
                                       rounds=ROUNDS,
                                       eval_every=EVAL_EVERY),
        label="fault.frontier_cold")
    misses = fl_driver.RUNNER_STATS["misses"] - m0
    assert misses == 1, (
        f"the whole (process x rate x seed) frontier must compile exactly "
        f"one runner, got {misses}")

    def warm():
        fl_driver.run_fl_sweep(fed, fl, cells, seeds=SEEDS, rounds=ROUNDS,
                               eval_every=EVAL_EVERY)

    t_warm, warm_walls = common.warm_min(warm, WARM_N)
    assert fl_driver.RUNNER_STATS["misses"] - m0 == 1, \
        "warm frontier reruns must be pure cache hits"

    frontier = []
    by_cell = {}
    for cell, row in zip(cells, sweep):
        proc = PROCESSES[int(cell["fault_process"])]
        rate = cell["failure_prob"]
        fail_obs = float(np.mean([x for r in row for x in r.history["fail"]]))
        entry = {
            "process": proc,
            "rate": rate,
            "acc_mean": float(np.mean([r.accuracy for r in row])),
            "auc_mean": float(np.mean([r.auc for r in row])),
            "sim_time_mean": float(np.mean([r.sim_time_s for r in row])),
            "fail_rate_observed": fail_obs,
        }
        frontier.append(entry)
        by_cell[(proc, rate)] = entry
        if proc == "straggler":
            assert fail_obs == 0.0, "stragglers must never register failures"
        elif rate > 0:
            # smoke grids have ~30 effective draws: sanity-band only there
            # (tests/test_fault.py pins the calibration tightly)
            tol = max(rate, 0.15) if SMOKE else max(0.75 * rate, 0.08)
            assert abs(fail_obs - rate) <= tol, (
                f"{proc} lane's observed failure rate {fail_obs:.3f} drifted "
                f"from its failure_prob lane {rate}")

    hi = RATES[-1]
    assert (by_cell[("straggler", hi)]["sim_time_mean"]
            > by_cell[("straggler", RATES[0])]["sim_time_mean"]), \
        "stragglers must stretch the simulated round time"

    # ---- coupling gate: bursty outages, reliability coupling on vs off ----
    # Both arms are runtime lanes (fault_util_w is an FLParams field), so
    # the comparison shares one compiled program by construction.
    gate_cells = [{"fault_process": process_code("markov"),
                   "failure_prob": GATE_RATE, "fault_burst": GATE_BURST,
                   "fault_util_w": w} for w in (GATE_W, 0.0)]
    mg = fl_driver.RUNNER_STATS["misses"]
    coupled, uncoupled = fl_driver.run_fl_sweep(
        fed, fl, gate_cells, seeds=GATE_SEEDS, rounds=GATE_ROUNDS,
        eval_every=EVAL_EVERY)
    assert fl_driver.RUNNER_STATS["misses"] - mg <= 1, \
        "the coupling gate grid must be at most one compile"
    t_coupled = [r.sim_time_s for r in coupled]
    t_uncoupled = [r.sim_time_s for r in uncoupled]
    u, p_val, significant = mannwhitney_greater(t_uncoupled, t_coupled)
    gate = bool(significant)

    # ---- FT ablation at the highest rate (paper §IV), recorded ungated ----
    noft_cells = [{"fault_process": process_code(p), "failure_prob": hi,
                   "fault_util_w": FAULT_W} for p in KILLING]
    m1 = fl_driver.RUNNER_STATS["misses"]
    noft = fl_driver.run_fl_sweep(fed, fl, noft_cells, seeds=SEEDS,
                                  method="proposed_noft", rounds=ROUNDS,
                                  eval_every=EVAL_EVERY)
    assert fl_driver.RUNNER_STATS["misses"] - m1 == 1, \
        "the no-FT static split must be exactly one more compile"
    acc_ft = [r.accuracy for p in KILLING for r in by_row(sweep, cells, p, hi)]
    acc_noft = [r.accuracy for row in noft for r in row]
    _, p_ablation, _ = mannwhitney_greater(acc_ft, acc_noft)

    n_lanes = len(cells) * len(SEEDS)
    report = {
        "mode": mode,
        "config": {"n_clients": N_CLIENTS, "rounds": ROUNDS,
                   "seeds": list(SEEDS), "rates": list(RATES),
                   "processes": list(PROCESSES), "fault_util_w": FAULT_W,
                   "n_lanes": n_lanes, "dataset": "unsw",
                   "backend": jax.default_backend(),
                   "n_devices": len(jax.devices())},
        "frontier": {
            "wall_s_cold": t_cold,
            "warm_execute_s_min": t_warm,
            "warm_execute_s_all": warm_walls,
            "warm_n": WARM_N,
            "runner_compiles": misses,
            "cells": frontier,
        },
        "coupling_gate": {
            "process": "markov",
            "rate": GATE_RATE,
            "burst": GATE_BURST,
            "fault_util_w": GATE_W,
            "rounds": GATE_ROUNDS,
            "seeds": list(GATE_SEEDS),
            "sim_time_coupled": t_coupled,
            "sim_time_uncoupled": t_uncoupled,
            "mannwhitney_u": u,
            "p_value": p_val,
            "coupling_saves_time": gate,
            "gated": not SMOKE,
        },
        "ft_ablation": {
            "rate": hi,
            "pooled_processes": list(KILLING),
            "acc_ft": acc_ft,
            "acc_noft": acc_noft,
            "p_value": p_ablation,
            "gated": False,
            "note": ("FT accuracy does not separate on the synthetic "
                     "stand-ins (mean aggregation over surviving complete "
                     "updates is already robust) — EXPERIMENTS.md "
                     "§Fault-frontier"),
        },
    }
    with open(OUT, "w") as f:
        json.dump(report, f, indent=1)

    common.record_bench("fault", [
        {"lane_key": "frontier", "statics_key": common.statics_key(fl),
         "wall_cold_s": t_cold, "warm_walls": warm_walls,
         "lane_params": {"n_lanes": n_lanes, "rounds": ROUNDS,
                         "rates": list(RATES)},
         "metrics": {"runner_compiles": float(misses)}},
    ] + [
        {"lane_key": f"{e['process']}@{e['rate']:.2f}",
         "statics_key": common.statics_key(fl),
         "lane_params": {"process": e["process"], "rate": e["rate"]},
         "metrics": {"auc_mean": (e["auc_mean"], 1),
                     "acc_mean": e["acc_mean"],
                     "sim_time_mean": e["sim_time_mean"],
                     "fail_rate_observed": e["fail_rate_observed"]}}
        for e in frontier
    ] + [
        {"lane_key": "coupling_gate", "statics_key": common.statics_key(fl),
         "lane_params": {"rate": GATE_RATE, "burst": GATE_BURST,
                         "rounds": GATE_ROUNDS},
         "metrics": {"p_value": p_val,
                     "coupling_saves_time": float(gate)}},
    ], mode=mode)

    print(f"  frontier x{n_lanes} lanes: {t_cold:7.2f}s cold, "
          f"{t_warm:.2f}s warm (min-of-{WARM_N}), 1 compile")
    for e in frontier:
        print(f"    {e['process']:>9s} rate {e['rate']:.2f}: "
              f"acc={e['acc_mean']:.3f} auc={e['auc_mean']:.3f} "
              f"fail_obs={e['fail_rate_observed']:.3f} "
              f"time={e['sim_time_mean']:6.1f}s")
    print(f"  coupling gate (markov rate {GATE_RATE}, burst {GATE_BURST:.0f}, "
          f"w {GATE_W:.0f} vs 0): sim time {np.mean(t_coupled):.1f}s vs "
          f"{np.mean(t_uncoupled):.1f}s -> Mann-Whitney p={p_val:.3e} "
          f"({'significant' if gate else 'ns'}"
          f"{', not gated in smoke' if SMOKE else ''})")
    print(f"  FT ablation @rate {hi} (ungated): acc {np.mean(acc_ft):.3f} vs "
          f"no-FT {np.mean(acc_noft):.3f} (p={p_ablation:.2e}; see "
          f"EXPERIMENTS.md §Fault-frontier)")
    print(f"  -> {os.path.abspath(OUT)}")

    csv_rows.append(("fault/frontier_cold_s", t_cold * 1e6,
                     n_lanes * ROUNDS / t_cold))
    csv_rows.append(("fault/coupling_p", 0.0, p_val))
    return report


def by_row(sweep, cells, proc, rate):
    """The per-seed results of one (process, rate) cell."""
    for cell, row in zip(cells, sweep):
        if (PROCESSES[int(cell["fault_process"])] == proc
                and cell["failure_prob"] == rate):
            return row
    raise KeyError((proc, rate))


if __name__ == "__main__":
    # Standalone (and CI) entry: compile-count and process-semantics
    # assertions raise always; the Mann-Whitney coupling gate exits
    # nonzero only in full mode (smoke grids are too small to gate on).
    report = run([])
    cg = report["coupling_gate"]
    if cg["gated"] and not cg["coupling_saves_time"]:
        raise SystemExit(
            f"fault coupling gate failed: reliability coupling does not "
            f"reduce simulated time under bursty outages "
            f"(p={cg['p_value']:.3e})")
