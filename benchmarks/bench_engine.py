"""Engine benchmark: legacy per-round Python loop vs compiled scan/vmap.

Measures, on one shared small config:

* ``legacy``      — ``run_fl_legacy`` single seed (host batch sampling + one
                    jit dispatch per round + per-round NumPy time model).
* ``scan``        — ``run_fl`` single seed (whole loop in one ``lax.scan``).
* ``batch``       — ``run_fl_batch`` over N seeds (vmap over the seed axis),
                    cold (includes compile) and warm (compiled program only,
                    the steady-state rounds/sec a sweep actually sees).

Also records the engine-equivalence deltas (final accuracy, ε) between the
two engines, and writes everything to ``BENCH_engine.json`` at the repo
root.  Acceptance gate (ISSUE 1): batch over >= 4 seeds must finish in
< 2x the wall time of ONE legacy single-seed run.

Timing protocol (ISSUE 3, hardening ISSUE 2's): the bench machine's wall
clocks are very noisy, so the ACCEPTANCE RATIO is computed from warm
MIN-OF-N timings only — batch = min-of-3 executes, legacy = min-of-2 runs
— never from a single cold wall.  Cold walls are still recorded, and the
one-off XLA compile is reported separately (``compile_s_est`` = cold wall
− min execute wall).
"""
from __future__ import annotations

import json
import os

import jax

from repro.configs.base import FLConfig
from repro.data.synthetic import make_federated
from repro.train import fl_driver

from benchmarks import common

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_engine.json")

N_CLIENTS = 32
ROUNDS = 150
SEEDS = (0, 1, 2, 3)
EVAL_EVERY = 10


def _bench_config() -> FLConfig:
    return FLConfig(
        n_clients=N_CLIENTS, clients_per_round=4, rounds=ROUNDS,
        local_epochs=5, local_batch=32, local_lr=0.08,
        dp_enabled=True, dp_mode="clipped", dp_epsilon=1000.0, dp_clip=1.0,
        fault_tolerance=True, failure_prob=0.05,
    )


def run(csv_rows: list) -> dict:
    print("\n== Engine: legacy Python loop vs compiled scan/vmap ==")
    fed = make_federated(0, "unsw", n_samples=8_000, n_clients=N_CLIENTS)
    fl = _bench_config()

    # min-of-2: the gate never reads a single run
    t_legacy, legacy_walls, legacy = common.wall_min(
        lambda: fl_driver.run_fl_legacy(fed, fl, "proposed", seed=0,
                                        rounds=ROUNDS,
                                        eval_every=EVAL_EVERY),
        2, label="engine.legacy")

    scan, t_scan = common.timed_call(
        lambda: fl_driver.run_fl(fed, fl, "proposed", seed=0, rounds=ROUNDS,
                                 eval_every=EVAL_EVERY),
        label="engine.scan_cold")

    def batch_call():
        return fl_driver.run_fl_batch(fed, fl, "proposed", seeds=SEEDS,
                                      rounds=ROUNDS, eval_every=EVAL_EVERY)

    _, t_batch = common.timed_call(batch_call, label="engine.batch_cold")

    # steady-state: later calls hit fl_driver's compiled-runner cache — this
    # is what every later cell/repetition of a sweep actually costs.  Min
    # of 3 (noisy shared machine; see module docstring).
    t_warm, warm_walls = common.warm_min(batch_call, 3)
    compile_s = max(t_batch - t_warm, 0.0)

    # telemetry overhead: the SAME warm cell with the host tracer recording
    # spans, against the tracer-off min-of-3 above.  The acceptance target
    # is ≤5% (ISSUE 8); recorded rather than hard-asserted because this
    # container's wall noise routinely exceeds 5% by itself — the store
    # history + tools/bench_regress.py is the durable guard.
    from repro.obs import TRACER
    was_enabled = TRACER.enabled
    TRACER.enable()
    try:
        t_traced, traced_walls = common.warm_min(batch_call, 3)
    finally:
        if not was_enabled:
            TRACER.disable()
    telemetry_ratio = t_traced / t_warm

    n_seeds = len(SEEDS)
    report = {
        "config": {"n_clients": N_CLIENTS, "rounds": ROUNDS,
                   "seeds": list(SEEDS), "local_epochs": fl.local_epochs,
                   "local_batch": fl.local_batch, "dataset": "unsw",
                   "backend": jax.default_backend()},
        "legacy_single": {
            "wall_s": t_legacy,
            "wall_s_all": legacy_walls,
            "rounds_per_s": ROUNDS / t_legacy,
        },
        "scan_single": {
            "wall_s": t_scan,
            "rounds_per_s": ROUNDS / t_scan,
        },
        "batch": {
            "n_seeds": n_seeds,
            "wall_s_cold": t_batch,
            "seed_rounds_per_s_cold": n_seeds * ROUNDS / t_batch,
            "execute_s_min_of_3": t_warm,
            "execute_s_all": warm_walls,
            "compile_s_est": compile_s,
            "wall_s_warm": t_warm,
            "seed_rounds_per_s_warm": n_seeds * ROUNDS / t_warm,
        },
        "speedup": {
            "warm_batch_vs_legacy_per_seed_round":
                (n_seeds * ROUNDS / t_warm) / (ROUNDS / t_legacy),
        },
        "acceptance": {
            # WARM ratio only (ISSUE 3): batch = warm min-of-3 (the cold
            # call pays the one-off XLA compile, recorded above), legacy =
            # min-of-2 runs.  No single cold wall enters the gate.
            "batch_wall_s": t_warm,
            "batch_wall_s_cold": t_batch,
            "legacy_single_wall_s": t_legacy,
            "ratio": t_warm / t_legacy,
            "pass_under_2x": bool(t_warm < 2.0 * t_legacy),
        },
        "equivalence": {
            "acc_legacy": legacy.accuracy,
            "acc_scan": scan.accuracy,
            "acc_abs_diff": abs(legacy.accuracy - scan.accuracy),
            "eps_legacy": legacy.eps_spent,
            "eps_scan": scan.eps_spent,
            "eps_abs_diff": abs(legacy.eps_spent - scan.eps_spent),
        },
        "telemetry": {
            "execute_s_min_off": t_warm,
            "execute_s_min_on": t_traced,
            "execute_s_all_on": traced_walls,
            "ratio": telemetry_ratio,
            "within_5pct": bool(telemetry_ratio <= 1.05),
        },
    }
    with open(OUT, "w") as f:
        json.dump(report, f, indent=1)

    common.record_bench("engine", [
        {"lane_key": "batch_warm", "statics_key": common.statics_key(fl),
         "wall_cold_s": t_batch, "warm_walls": warm_walls,
         "lane_params": {"n_clients": N_CLIENTS, "rounds": ROUNDS,
                         "n_seeds": n_seeds},
         "metrics": {"acceptance_ratio": (report["acceptance"]["ratio"], -1),
                     "acc_abs_diff": report["equivalence"]["acc_abs_diff"],
                     "telemetry_ratio": telemetry_ratio}},
        {"lane_key": "legacy_single", "statics_key": common.statics_key(fl),
         "warm_walls": legacy_walls,
         "lane_params": {"n_clients": N_CLIENTS, "rounds": ROUNDS}},
    ])

    print(f"  legacy single-seed : {t_legacy:7.2f}s min-of-2 "
          f"({ROUNDS / t_legacy:6.1f} rounds/s)")
    print(f"  scan   single-seed : {t_scan:7.2f}s "
          f"({ROUNDS / t_scan:6.1f} rounds/s, incl. compile)")
    print(f"  batch x{n_seeds} cold      : {t_batch:7.2f}s "
          f"({n_seeds * ROUNDS / t_batch:6.1f} seed-rounds/s, "
          f"compile ~{compile_s:.2f}s)")
    print(f"  batch x{n_seeds} warm      : {t_warm:7.2f}s min-of-3 "
          f"({n_seeds * ROUNDS / t_warm:6.1f} seed-rounds/s)")
    print(f"  acceptance: batch x{n_seeds} < 2x legacy single -> "
          f"{report['acceptance']['pass_under_2x']} "
          f"(ratio {report['acceptance']['ratio']:.2f})")
    print(f"  equivalence: |acc diff| = "
          f"{report['equivalence']['acc_abs_diff']:.4f}, |eps diff| = "
          f"{report['equivalence']['eps_abs_diff']:.2e}")
    print(f"  telemetry overhead: {telemetry_ratio:.3f}x warm "
          f"(target <=1.05: {report['telemetry']['within_5pct']})")
    print(f"  -> {os.path.abspath(OUT)}")

    csv_rows.append(("engine/legacy_single_rps", t_legacy * 1e6 / ROUNDS,
                     ROUNDS / t_legacy))
    csv_rows.append(("engine/scan_single_rps", t_scan * 1e6 / ROUNDS,
                     ROUNDS / t_scan))
    csv_rows.append(("engine/batch_warm_seed_rps",
                     t_warm * 1e6 / (n_seeds * ROUNDS),
                     n_seeds * ROUNDS / t_warm))
    return report


if __name__ == "__main__":
    run([])
