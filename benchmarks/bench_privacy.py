"""Privacy-subsystem benchmark (ISSUE 3): the compiled budget frontier.

Sections, written to ``BENCH_privacy.json`` at the repo root:

* ``frontier`` — the ε-vs-AUC frontier: ≥4 TOTAL privacy budgets × ≥4
  seeds with **adaptive** budget scheduling, all lanes in ONE compiled
  program (``dp_budget``/``dp_sched`` are runtime FLParams lanes).  Hard
  assertion: exactly one ``_get_runner`` miss for the whole grid.
* ``overhead`` — the in-scan accountant + scheduler cost vs the PR 2
  engine: the same (shape, statics) cell with ``dp_scheduled`` off vs on.
  Timing protocol (repo memory: very noisy wall clocks): both sides are
  warm MIN-OF-N executes — a cold wall never enters the ratio.
  Acceptance: ratio ≤ 1.05 (the accountant is ~30 scalar flops/round next
  to a 24-client training step; exit code gates only when run standalone
  in full mode).
* ``offline_check`` — hard assertion: a uniform-schedule, fixed-K lane's
  final accounted ε (the f32 in-scan accountant) matches the f64
  closed-form RDP composition at the engine's own σ within 1e-6
  (relative) — the acceptance bound, re-verified on every run.

``REPRO_PRIVACY_SMOKE=1`` shrinks the grid (2 budgets × 2 seeds × few
rounds) and skips the wall-clock gate — correctness assertions stay on.
"""
from __future__ import annotations

import dataclasses
import json
import os

import jax
import numpy as np

from repro.configs.base import FLConfig
from repro.data.synthetic import make_federated
from repro.privacy import accountant as acct_lib
from repro.privacy import schedule as sched_lib
from repro.train import fl_driver

from benchmarks import common

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_privacy.json")

SMOKE = os.environ.get("REPRO_PRIVACY_SMOKE", "0") == "1"
N_CLIENTS = 8 if SMOKE else 24
N_SAMPLES = 1_200 if SMOKE else 6_000
ROUNDS = 10 if SMOKE else 60
SEEDS = (0, 1) if SMOKE else (0, 1, 2, 3)
BUDGETS = (300.0, 3000.0) if SMOKE else (300.0, 1000.0, 3000.0, 10000.0)
EVAL_EVERY = 5 if SMOKE else 10
WARM_N = 3 if SMOKE else 5


def _bench_config(**kw) -> FLConfig:
    return FLConfig(
        n_clients=N_CLIENTS, clients_per_round=4, rounds=ROUNDS,
        local_epochs=5, local_batch=32, local_lr=0.08,
        dp_enabled=True, dp_mode="clipped", dp_epsilon=1000.0, dp_clip=1.0,
        fault_tolerance=True, failure_prob=0.05, **kw)


def run(csv_rows: list) -> dict:
    mode = "smoke" if SMOKE else "full"
    print(f"\n== Privacy: budget frontier + accountant overhead ({mode}) ==")
    fed = make_federated(0, "unsw", n_samples=N_SAMPLES, n_clients=N_CLIENTS)

    # ---- frontier: adaptive scheduling, one compiled program ----
    fl = _bench_config(dp_scheduled=True,
                      dp_sched=sched_lib.schedule_code("adaptive"))
    cells = [{"dp_budget": b} for b in BUDGETS]
    fl_driver._RUNNER_CACHE.clear()
    m0 = fl_driver.RUNNER_STATS["misses"]
    sweep, t_frontier_cold = common.timed_call(
        lambda: fl_driver.run_fl_sweep(fed, fl, cells, seeds=SEEDS,
                                       rounds=ROUNDS,
                                       eval_every=EVAL_EVERY),
        label="privacy.frontier_cold")
    misses = fl_driver.RUNNER_STATS["misses"] - m0
    assert misses == 1, (
        f"the whole budget frontier must compile exactly one runner, got "
        f"{misses}")

    frontier = []
    for budget, row in zip(BUDGETS, sweep):
        frontier.append({
            "budget": budget,
            "auc_mean": float(np.mean([r.auc for r in row])),
            "acc_mean": float(np.mean([r.accuracy for r in row])),
            "eps_spent_mean": float(np.mean([r.eps_spent for r in row])),
            "sigma_first": row[0].history["sigma"][0],
            "sigma_last": row[0].history["sigma"][-1],
            "live_frac_last": float(np.mean(
                [r.history["live"][-1] for r in row])),
        })
        assert all(r.eps_spent <= budget * (1 + 1e-5) for r in row), \
            "accounted ε overshot the lane's budget"

    # ---- overhead: scheduled vs PR 2 fixed-σ engine, warm min-of-N ----
    base = _bench_config()           # dp_scheduled=False — the PR 2 path
    sched = _bench_config(dp_scheduled=True)

    def run_base():
        fl_driver.run_fl_batch(fed, base, "proposed", seeds=SEEDS,
                               rounds=ROUNDS, eval_every=EVAL_EVERY)

    def run_sched():
        fl_driver.run_fl_batch(fed, sched, "proposed", seeds=SEEDS,
                               rounds=ROUNDS, eval_every=EVAL_EVERY)

    run_base()    # compile both programs before any timed call
    run_sched()
    t_base, base_walls = common.warm_min(run_base, WARM_N)
    t_sched, sched_walls = common.warm_min(run_sched, WARM_N)
    overhead = t_sched / t_base
    gate = bool(overhead <= 1.05)

    # ---- offline check: in-scan ε == f64 composition at the engine's σ ----
    fixed = _bench_config(dp_scheduled=True, adaptive_k=False)
    res = fl_driver.run_fl_batch(fed, fixed, "proposed", seeds=(0,),
                                 rounds=ROUNDS, eval_every=EVAL_EVERY)[0]
    # compose offline over the rounds the engine actually RELEASED — the
    # calibration converges z to the budget threshold with sub-ulp margin,
    # so the very last round may legitimately land a ulp over and be gated;
    # anything more than that would be a real calibration bug.
    block_lens = [EVAL_EVERY] * (ROUNDS // EVAL_EVERY)
    if ROUNDS % EVAL_EVERY:
        block_lens.append(ROUNDS % EVAL_EVERY)
    released = int(round(sum(f * b for f, b in
                             zip(res.history["live"], block_lens))))
    assert released >= ROUNDS - 1, (
        f"uniform calibration released only {released}/{ROUNDS} rounds")
    z_engine = float(np.float32(res.history["sigma"][0])) / fixed.dp_clip
    q = float(np.float32(fixed.clients_per_round / fixed.n_clients))
    eps_offline = acct_lib.compose_epsilon(z_engine, q, released,
                                           fixed.dp_delta)
    eps_err = abs(res.eps_spent - eps_offline) / max(1.0, abs(eps_offline))
    assert eps_err <= 1e-6, (
        f"in-scan accountant drifted from the offline RDP reference: "
        f"{res.eps_spent} vs {eps_offline} (rel {eps_err:.2e})")

    n_lanes = len(BUDGETS) * len(SEEDS)
    report = {
        "mode": mode,
        "config": {"n_clients": N_CLIENTS, "rounds": ROUNDS,
                   "seeds": list(SEEDS), "budgets": list(BUDGETS),
                   "n_lanes": n_lanes, "dataset": "unsw",
                   "backend": jax.default_backend(),
                   "n_devices": len(jax.devices())},
        "frontier": {
            "schedule": "adaptive",
            "wall_s_cold": t_frontier_cold,
            "runner_compiles": misses,
            "cells": frontier,
        },
        "overhead": {
            "baseline_execute_s_min": t_base,
            "baseline_execute_s_all": base_walls,
            "scheduled_execute_s_min": t_sched,
            "scheduled_execute_s_all": sched_walls,
            "warm_n": WARM_N,
            "ratio": overhead,
            "pass_within_5pct": gate,
            "gated": not SMOKE,
        },
        "offline_check": {
            "z": z_engine,
            "q": q,
            "released_rounds": released,
            "eps_in_scan": res.eps_spent,
            "eps_offline_f64": eps_offline,
            "rel_err": eps_err,
        },
    }
    with open(OUT, "w") as f:
        json.dump(report, f, indent=1)

    common.record_bench("privacy", [
        {"lane_key": f"budget{c['budget']:.0f}",
         "statics_key": common.statics_key(fl),
         "lane_params": {"budget": c["budget"], "rounds": ROUNDS,
                         "seeds": list(SEEDS)},
         "metrics": {"auc_mean": (c["auc_mean"], 1),
                     "eps_spent_mean": c["eps_spent_mean"],
                     "live_frac_last": c["live_frac_last"]}}
        for c in frontier
    ] + [
        {"lane_key": "overhead", "statics_key": common.statics_key(sched),
         "warm_walls": sched_walls,
         "lane_params": {"warm_n": WARM_N},
         "metrics": {"overhead_ratio": (overhead, -1),
                     "accountant_rel_err": eps_err}},
    ], mode=mode)

    print(f"  frontier x{n_lanes} lanes (adaptive): "
          f"{t_frontier_cold:7.2f}s cold, 1 compile")
    for c in frontier:
        print(f"    budget {c['budget']:8.0f}: auc={c['auc_mean']:.3f} "
              f"eps={c['eps_spent_mean']:9.2f} "
              f"sigma {c['sigma_first']:.4f}->{c['sigma_last']:.4f} "
              f"live={c['live_frac_last']:.2f}")
    print(f"  overhead: scheduled {t_sched:.2f}s vs baseline {t_base:.2f}s "
          f"(warm min-of-{WARM_N}) -> ratio {overhead:.3f} "
          f"(<=1.05: {gate}{', not gated in smoke' if SMOKE else ''})")
    print(f"  offline ε check: |rel err| = {eps_err:.2e} (<= 1e-6)")
    print(f"  -> {os.path.abspath(OUT)}")

    csv_rows.append(("privacy/frontier_cold_s", t_frontier_cold * 1e6,
                     n_lanes * ROUNDS / t_frontier_cold))
    csv_rows.append(("privacy/overhead_ratio", t_sched * 1e6, overhead))
    return report


if __name__ == "__main__":
    # Standalone (and CI) entry: correctness assertions raise always; the
    # warm-wall overhead gate exits nonzero only in full mode, so one noisy
    # timing cannot abort the rest of benchmarks/run.py.
    report = run([])
    if report["overhead"]["gated"] and not report["overhead"]["pass_within_5pct"]:
        raise SystemExit(
            f"privacy overhead gate failed: ratio "
            f"{report['overhead']['ratio']:.3f} > 1.05")
