"""Table I — detection performance: proposed vs ACFL vs FedL2P (+ random).

Paper reports (UNSW-NB15): ACFL 87.8%/0.86/760s, FedL2P 92.1%/0.91/600s,
Proposed 94.8%/0.93/570s; (ROAD): 83.3/0.81/905, 88.7/0.86/710, 90.3/0.88/680.

On the synthetic stand-ins we validate the paper's *relative* claims:
  (1) accuracy ordering Proposed > FedL2P > ACFL on both datasets,
  (2) the training-time metric — time-to-target-accuracy — is lowest for
      Proposed (its utility score prefers fast, clean clients; ACFL's
      loss-seeking picks the corrupted ones; FedL2P pays personalisation).

All seeds of each (method, dataset) cell run as one compiled scan/vmap
program (benchmarks/common.py -> run_fl_batch).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import ROUNDS, run_grid

METHODS = ("acfl", "fedl2p", "proposed", "random")
DATASETS = ("unsw", "road")
ACC_TARGET = {"unsw": 0.85, "road": 0.60}


def _tta(row, target):
    for t, a in zip(row["history"].get("cum_time", []),
                    row["history"].get("acc", [])):
        if a >= target:
            return t
    return float("inf")


def run(csv_rows: list):
    rows = run_grid(METHODS, DATASETS)
    print("\n== Table I: method comparison (means over seeds) ==")
    print(f"{'dataset':8s} {'method':12s} {'acc%':>7s} {'auc':>7s} "
          f"{'t_total(s)':>11s} {'t->target(s)':>13s}")
    summary = {}
    for ds in DATASETS:
        for m in METHODS:
            sel = [r for r in rows if r["method"] == m and r["dataset"] == ds]
            acc = float(np.mean([r["accuracy"] for r in sel])) * 100
            auc = float(np.mean([r["auc"] for r in sel]))
            t = float(np.mean([r["sim_time_s"] for r in sel]))
            ttas = [_tta(r, ACC_TARGET[ds]) for r in sel]
            tta = float(np.mean([x for x in ttas if np.isfinite(x)] or [np.inf]))
            summary[(ds, m)] = (acc, auc, t, tta)
            print(f"{ds:8s} {m:12s} {acc:7.1f} {auc:7.3f} {t:11.1f} {tta:13.1f}")
            csv_rows.append((f"table1/{ds}/{m}/acc_pct", t * 1e6 / ROUNDS, acc))
            csv_rows.append((f"table1/{ds}/{m}/auc", tta * 1e6, auc))
    for ds in DATASETS:
        order_ok = (summary[(ds, "proposed")][0] > summary[(ds, "fedl2p")][0]
                    > summary[(ds, "acfl")][0])
        faster = summary[(ds, "proposed")][3] <= min(
            summary[(ds, "fedl2p")][3], summary[(ds, "acfl")][3])
        print(f"claim[{ds}]: acc ordering proposed>fedl2p>acfl -> {order_ok}; "
              f"proposed fastest to {ACC_TARGET[ds]*100:.0f}% acc -> {faster}")
    return rows


if __name__ == "__main__":
    run([])
