"""Sweep-engine benchmark: one program per sweep vs one program per cell.

The ISSUE-2 acceptance experiment: a 4-point ε sweep × 4 seeds on one
(method, dataset) shape.

* ``percell`` — the PRE-REFACTOR behaviour: every config cell compiles its
  own runner (reproduced faithfully by clearing the runner cache before
  each cell), then executes its seed batch.  This is what the engine did
  when the cache keyed on the full FLConfig.
* ``percell_shared`` — the same per-cell loop under the new static-keyed
  cache: the first cell compiles, later cells are cache hits that still
  dispatch one program per cell.
* ``sweep`` — ``run_fl_sweep``: all 16 seed×ε lanes in ONE compiled
  program, ε as a runtime FLParams lane.

Timing protocol (noisy machine, see repo memory/EXPERIMENTS.md; hardened
in ISSUE 3): warm (execute-only) walls are the MIN OF 3; compile cost is
reported separately as ``compile_s_est`` = cold wall − min execute wall.

Checks:
* single-compile property (hard failure, also enforced by the CI smoke
  job) — the sweep takes exactly ONE ``_get_runner`` miss for the grid;
* lane-for-lane equality (hard failure) — every sweep lane matches the
  per-cell engine's result for the same (ε, seed), ε exactly;
* acceptance (full mode) — computed from warm MIN-OF-N walls ONLY, never
  a single cold run: one batched sweep execute must beat the per-cell
  path's four warm dispatches (ratio ≤ 1).  The cold-vs-cold ratio
  (ISSUE 2's ≤ ½ amortisation claim) is still recorded, unaudited — a
  single cold wall is not gate material on this machine.  The verdict
  turns into a nonzero exit code only when run standalone (so one noisy
  timing cannot abort the rest of ``benchmarks/run.py``).

Writes ``BENCH_sweep.json`` at the repo root.  ``REPRO_SWEEP_SMOKE=1``
shrinks the grid (2 ε × 2 seeds × few rounds) and skips the wall-clock
gate — correctness assertions stay on.
"""
from __future__ import annotations

import json
import os

import dataclasses

import jax
import numpy as np

from repro.configs.base import FLConfig
from repro.data.synthetic import make_federated
from repro.train import fl_driver

from benchmarks import common

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_sweep.json")

SMOKE = os.environ.get("REPRO_SWEEP_SMOKE", "0") == "1"
N_CLIENTS = 8 if SMOKE else 24
N_SAMPLES = 1_200 if SMOKE else 6_000
ROUNDS = 10 if SMOKE else 60
SEEDS = (0, 1) if SMOKE else (0, 1, 2, 3)
EPSILONS = (100.0, 1000.0) if SMOKE else (30.0, 100.0, 300.0, 1000.0)
EVAL_EVERY = 5 if SMOKE else 10


def _bench_config() -> FLConfig:
    return FLConfig(
        n_clients=N_CLIENTS, clients_per_round=4, rounds=ROUNDS,
        local_epochs=5, local_batch=32, local_lr=0.08,
        dp_enabled=True, dp_mode="clipped", dp_epsilon=1000.0, dp_clip=1.0,
        fault_tolerance=True, failure_prob=0.05,
    )


def _clear_runner_cache():
    fl_driver._RUNNER_CACHE.clear()


def run(csv_rows: list) -> dict:
    mode = "smoke" if SMOKE else "full"
    print(f"\n== Sweep engine: one program per sweep vs per cell ({mode}) ==")
    fed = make_federated(0, "unsw", n_samples=N_SAMPLES, n_clients=N_CLIENTS)
    fl = _bench_config()
    cells = [dataclasses.replace(fl, dp_epsilon=e) for e in EPSILONS]
    n_lanes = len(cells) * len(SEEDS)

    # ---- per-cell, pre-refactor behaviour: one compile per cell ----
    percell_results = []
    percell_walls = []
    for cell in cells:
        _clear_runner_cache()  # pre-refactor: each cell paid its own compile
        res, wall = common.timed_call(
            lambda cell=cell: fl_driver.run_fl_batch(
                fed, cell, "proposed", seeds=SEEDS, rounds=ROUNDS,
                eval_every=EVAL_EVERY),
            label="sweep.percell_cold")
        percell_results.append(res)
        percell_walls.append(wall)
    t_percell_cold = sum(percell_walls)

    # ---- per-cell under the new static-keyed cache (hits after cell 0) ----
    _clear_runner_cache()

    def _percell_all():
        for cell in cells:
            fl_driver.run_fl_batch(fed, cell, "proposed", seeds=SEEDS,
                                   rounds=ROUNDS, eval_every=EVAL_EVERY)

    _, t_percell_shared_cold = common.timed_call(
        _percell_all, label="sweep.percell_shared_cold")
    def _percell_pass():
        for cell in cells:
            fl_driver.run_fl_batch(fed, cell, "proposed", seeds=SEEDS,
                                   rounds=ROUNDS, eval_every=EVAL_EVERY)

    t_percell_exec, percell_exec = common.warm_min(_percell_pass, 3)

    # ---- the sweep: one program for the whole grid ----
    _clear_runner_cache()
    m0 = fl_driver.RUNNER_STATS["misses"]
    sweep, t_sweep_cold = common.timed_call(
        lambda: fl_driver.run_fl_sweep(fed, fl, cells, seeds=SEEDS,
                                       rounds=ROUNDS,
                                       eval_every=EVAL_EVERY),
        label="sweep.cold")
    sweep_misses = fl_driver.RUNNER_STATS["misses"] - m0
    t_sweep_exec, sweep_exec = common.warm_min(
        lambda: fl_driver.run_fl_sweep(fed, fl, cells, seeds=SEEDS,
                                       rounds=ROUNDS, eval_every=EVAL_EVERY),
        3)

    # ---- correctness: lane-for-lane vs the per-cell engine ----
    assert sweep_misses == 1, (
        f"sweep must compile exactly one runner for the grid, got "
        f"{sweep_misses}")
    acc_diff = max(
        abs(lane.accuracy - ref.accuracy)
        for row, refs in zip(sweep, percell_results)
        for lane, ref in zip(row, refs))
    hist_diff = max(
        float(np.max(np.abs(np.asarray(lane.history["acc"])
                            - np.asarray(ref.history["acc"]))))
        for row, refs in zip(sweep, percell_results)
        for lane, ref in zip(row, refs))
    assert all(
        lane.eps_spent == ref.eps_spent
        for row, refs in zip(sweep, percell_results)
        for lane, ref in zip(row, refs)), "reported ε must match exactly"
    assert acc_diff <= 1e-4 and hist_diff <= 1e-4, (acc_diff, hist_diff)

    # acceptance ratio: WARM min-of-3 only (cold ratio recorded, unaudited)
    ratio = t_sweep_exec / t_percell_exec
    gate = bool(ratio <= 1.0)
    cold_ratio = t_sweep_cold / t_percell_cold
    report = {
        "mode": mode,
        "config": {"n_clients": N_CLIENTS, "rounds": ROUNDS,
                   "seeds": list(SEEDS), "epsilons": list(EPSILONS),
                   "n_lanes": n_lanes, "dataset": "unsw",
                   "backend": jax.default_backend(),
                   "n_devices": len(jax.devices())},
        "percell": {
            # pre-refactor: runner cache keyed on the full FLConfig, so each
            # ε cell compiled its own program (reproduced by clearing the
            # cache per cell)
            "wall_s_cold": t_percell_cold,
            "wall_s_per_cell": percell_walls,
        },
        "percell_shared": {
            "wall_s_cold": t_percell_shared_cold,
            "execute_s_min_of_3": t_percell_exec,
            "execute_s_all": percell_exec,
            "compile_s_est": max(t_percell_shared_cold - t_percell_exec, 0.0),
        },
        "sweep": {
            "wall_s_cold": t_sweep_cold,
            "execute_s_min_of_3": t_sweep_exec,
            "execute_s_all": sweep_exec,
            "compile_s_est": max(t_sweep_cold - t_sweep_exec, 0.0),
            "runner_compiles": sweep_misses,
            "lane_seconds_cold": t_sweep_cold / n_lanes,
        },
        "equivalence": {
            "max_abs_acc_diff": acc_diff,
            "max_abs_history_acc_diff": hist_diff,
            "eps_exact": True,
        },
        "acceptance": {
            # warm-only gate (ISSUE 3): one batched execute vs 4 warm
            # per-cell dispatches, both min-of-3
            "sweep_execute_s": t_sweep_exec,
            "percell_execute_s": t_percell_exec,
            "ratio": ratio,
            "pass_warm_not_slower": gate,
            # ISSUE 2's cold amortisation, recorded but never gated
            "sweep_cold_s": t_sweep_cold,
            "percell_cold_s": t_percell_cold,
            "cold_ratio": cold_ratio,
            "gated": not SMOKE,
        },
    }
    with open(OUT, "w") as f:
        json.dump(report, f, indent=1)

    common.record_bench("sweep", [
        {"lane_key": "sweep_warm", "statics_key": common.statics_key(fl),
         "wall_cold_s": t_sweep_cold, "warm_walls": sweep_exec,
         "lane_params": {"n_lanes": n_lanes, "rounds": ROUNDS,
                         "epsilons": list(EPSILONS)},
         "metrics": {"acceptance_ratio": (ratio, -1),
                     "max_abs_acc_diff": acc_diff}},
        {"lane_key": "percell_warm", "statics_key": common.statics_key(fl),
         "wall_cold_s": t_percell_shared_cold, "warm_walls": percell_exec,
         "lane_params": {"n_cells": len(cells), "rounds": ROUNDS}},
    ], mode=mode)

    print(f"  per-cell (compile per cell) : {t_percell_cold:7.2f}s cold "
          f"({len(cells)} compiles)")
    print(f"  per-cell (shared program)   : {t_percell_shared_cold:7.2f}s cold, "
          f"{t_percell_exec:.2f}s execute (min-of-3)")
    print(f"  sweep x{n_lanes} lanes           : {t_sweep_cold:7.2f}s cold "
          f"(1 compile), {t_sweep_exec:.2f}s execute (min-of-3)")
    print(f"  acceptance: sweep warm <= per-cell warm -> {gate} "
          f"(ratio {ratio:.2f}, cold ratio {cold_ratio:.2f} recorded"
          f"{', not gated in smoke' if SMOKE else ''})")
    print(f"  equivalence: max |acc diff| = {acc_diff:.2e} "
          f"(lane-for-lane, ε exact)")
    print(f"  -> {os.path.abspath(OUT)}")

    csv_rows.append(("sweep/percell_cold_s", t_percell_cold * 1e6, ratio))
    csv_rows.append(("sweep/sweep_cold_s", t_sweep_cold * 1e6,
                     n_lanes * ROUNDS / t_sweep_cold))
    csv_rows.append(("sweep/execute_median_s", t_sweep_exec * 1e6,
                     n_lanes * ROUNDS / t_sweep_exec))
    return report


if __name__ == "__main__":
    # Standalone (and CI) entry: signal a failed full-mode wall-clock gate
    # via the exit code.  Inside benchmarks/run.py the verdict is only
    # recorded in BENCH_sweep.json, so one noisy timing can't abort the
    # remaining table benches.  Correctness assertions raise either way.
    report = run([])
    if report["acceptance"]["gated"] and not report["acceptance"]["pass_warm_not_slower"]:
        raise SystemExit(
            f"sweep acceptance failed: warm ratio "
            f"{report['acceptance']['ratio']:.2f} > 1.0")
